//! Property tests for the fleet layer: the degenerate-mode equivalence
//! contract (`shards = 1, max_staleness = 0` ≡ the flat coordinator,
//! bit-for-bit), the hierarchical fold's exactness, and shard-partition
//! invariants (mock backend — no artifacts needed).

use cnc_fl::cnc::optimize::{CohortStrategy, RbStrategy};
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::MockTrainer;
use cnc_fl::fleet::{self, FleetConfig, FleetShards, RootAggregator, ShardBy, ShardUpdate};
use cnc_fl::metrics::RunHistory;
use cnc_fl::model::aggregate::weighted_average;
use cnc_fl::model::params::ModelParams;
use cnc_fl::model::shape::{ModelShape, PRESET_NAMES};
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::util::propcheck::{check, gen_usize, prop_assert, GenPair};
use cnc_fl::util::rng::Pcg64;

fn system(n: usize, seed: u64) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 2;
    CncSystem::bootstrap(n, 600, 1, PowerProfile::Bimodal, ch, seed)
}

/// Bitwise comparison of the fields both engines fill (compute_wall_s is
/// wall-clock and the shard columns are fleet-only by design).
fn assert_histories_identical(a: &RunHistory, b: &RunHistory) -> Result<(), String> {
    if a.rounds.len() != b.rounds.len() {
        return Err(format!(
            "round counts differ: {} vs {}",
            a.rounds.len(),
            b.rounds.len()
        ));
    }
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        if x.accuracy.to_bits() != y.accuracy.to_bits() {
            return Err(format!(
                "round {}: accuracy {} vs {}",
                x.round, x.accuracy, y.accuracy
            ));
        }
        if x.train_loss.to_bits() != y.train_loss.to_bits() {
            return Err(format!(
                "round {}: loss {} vs {}",
                x.round, x.train_loss, y.train_loss
            ));
        }
        if x.local_delays_s != y.local_delays_s
            || x.tx_delays_s != y.tx_delays_s
            || x.tx_energies_j != y.tx_energies_j
            || x.dropouts != y.dropouts
        {
            return Err(format!("round {}: decision telemetry differs", x.round));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// degenerate mode ≡ flat coordinator
// ---------------------------------------------------------------------------

#[test]
fn one_shard_sync_fleet_equals_traditional_for_any_seed_and_width() {
    check(
        6,
        GenPair(gen_usize(15..40), gen_usize(0..10_000)),
        |&(u, seed)| {
            let cohort = (u / 3).max(2);
            let m = (u / cohort).clamp(1, u);
            for threads in [1usize, 4] {
                let trad = {
                    let mut sys = system(u, seed as u64);
                    let mut t = MockTrainer::new(u, 600);
                    let cfg = TraditionalConfig {
                        rounds: 3,
                        cohort_size: cohort,
                        n_rb: cohort,
                        epoch_local: 2,
                        cohort_strategy: CohortStrategy::PowerGrouping { m },
                        rb_strategy: RbStrategy::HungarianEnergy,
                        eval_every: 1,
                        tx_deadline_s: None,
                        threads,
                        seed: seed as u64,
                        verbose: false,
                    };
                    traditional::run(&mut sys, &mut t, &cfg, "flat").unwrap()
                };
                let flt = {
                    let mut sys = system(u, seed as u64);
                    let mut t = MockTrainer::new(u, 600);
                    let cfg = FleetConfig {
                        rounds: 3,
                        shards: 1,
                        max_staleness: 0,
                        cohort_size: cohort,
                        n_rb: cohort,
                        epoch_local: 2,
                        cohort_strategy: CohortStrategy::PowerGrouping { m },
                        rb_strategy: RbStrategy::HungarianEnergy,
                        threads,
                        seed: seed as u64,
                        ..Default::default()
                    };
                    fleet::run(&mut sys, &mut t, &cfg, "fleet").unwrap()
                };
                assert_histories_identical(&trad, &flt)
                    .map_err(|e| format!("threads {threads}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn degenerate_mode_holds_for_uniform_cohorts_too() {
    // FedAvg-style strategies go through different decision code paths;
    // the degenerate contract must hold there as well
    let seed = 77u64;
    let trad = {
        let mut sys = system(30, seed);
        let mut t = MockTrainer::new(30, 600);
        let cfg = TraditionalConfig {
            rounds: 4,
            cohort_size: 6,
            n_rb: 8,
            cohort_strategy: CohortStrategy::Uniform,
            rb_strategy: RbStrategy::Random,
            seed,
            ..Default::default()
        };
        traditional::run(&mut sys, &mut t, &cfg, "flat").unwrap()
    };
    let flt = {
        let mut sys = system(30, seed);
        let mut t = MockTrainer::new(30, 600);
        let cfg = FleetConfig {
            rounds: 4,
            shards: 1,
            max_staleness: 0,
            cohort_size: 6,
            n_rb: 8,
            cohort_strategy: CohortStrategy::Uniform,
            rb_strategy: RbStrategy::Random,
            seed,
            ..Default::default()
        };
        fleet::run(&mut sys, &mut t, &cfg, "fleet").unwrap()
    };
    assert_histories_identical(&trad, &flt).unwrap();
}

// ---------------------------------------------------------------------------
// hierarchical fold ≡ flat weighted average (0 ULP on integer inputs)
// ---------------------------------------------------------------------------

fn integer_params(seed: u64) -> ModelParams {
    // small integer values: every partial sum stays exactly representable
    // in f32 (well under 2^24), so regrouping cannot round
    let mut rng = Pcg64::seed_from(seed);
    let mut m = ModelParams::zeros(&ModelShape::paper());
    for v in m.as_mut_slice() {
        *v = rng.range_i64(-8, 8) as f32;
    }
    m
}

#[test]
fn hierarchical_fold_is_0ulp_equal_to_flat_on_integer_weights() {
    check(
        15,
        GenPair(gen_usize(2..12), gen_usize(0..1_000_000)),
        |&(n, seed)| {
            let mut rng = Pcg64::seed_from(seed as u64 ^ 0x51A6);
            let updates: Vec<(ModelParams, usize)> = (0..n)
                .map(|i| {
                    let m = integer_params(seed as u64 * 131 + i as u64);
                    let w = rng.below(7) as usize + 1;
                    (m, w)
                })
                .collect();
            let flat = weighted_average(&updates)
                .map_err(|e| format!("weighted_average: {e}"))?;

            // random contiguous two-level grouping of the same updates in
            // the same order
            let shape = ModelShape::paper();
            let cuts = rng.below(n as u64 - 1) as usize + 1; // 1..n shards
            let mut root = RootAggregator::new(&shape, 0, 1.0);
            let mut idx = 0usize;
            for shard in 0..cuts {
                let hi = if shard + 1 == cuts {
                    n
                } else {
                    (idx + (n - idx) / (cuts - shard)).max(idx + 1)
                };
                let mut upd = ShardUpdate::new(&shape, shard, 0);
                for (m, w) in &updates[idx..hi] {
                    upd.push(m, *w);
                }
                idx = hi;
                root.offer(&upd, 0);
            }
            let hier = root.finish().map_err(|e| format!("finish: {e}"))?;
            let bitwise_equal = flat
                .as_slice()
                .iter()
                .zip(hier.as_slice())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            prop_assert(bitwise_equal, "two-level fold drifted from flat fold")
        },
    );
}

// ---------------------------------------------------------------------------
// shard-partition invariants
// ---------------------------------------------------------------------------

#[test]
fn shards_always_partition_and_views_always_match() {
    check(
        20,
        GenPair(gen_usize(4..120), gen_usize(0..10_000)),
        |&(u, seed)| {
            let sys = system(u, seed as u64);
            let k = (u / 4).max(1).min(9);
            for by in [ShardBy::Locality, ShardBy::Power] {
                let f = FleetShards::build(&sys.pool, k, by)
                    .map_err(|e| format!("build: {e}"))?;
                let mut all: Vec<usize> =
                    f.shards.iter().flat_map(|s| s.members.clone()).collect();
                all.sort_unstable();
                prop_assert(
                    all == (0..u).collect::<Vec<_>>(),
                    "shards must partition the fleet",
                )?;
                for s in &f.shards {
                    let sorted = s.members.windows(2).all(|w| w[0] < w[1]);
                    prop_assert(sorted, "members must be id-sorted")?;
                    for (local, &c) in s.members.iter().enumerate() {
                        prop_assert(
                            s.pool.fleet.delays_s[local] == sys.pool.fleet.delays_s[c]
                                && s.pool.fleet.data_sizes[local]
                                    == sys.pool.fleet.data_sizes[c],
                            "shard view must mirror the global pool",
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// model-size scenario axis: one binary, several arenas
// ---------------------------------------------------------------------------

#[test]
fn fleet_engine_runs_every_shape_preset_without_recompiling() {
    // the dynamic arena's acceptance bar: full sharded/async fleet rounds
    // over all three model sizes in one process, each training the arena
    // its shape declares
    let seed = 5u64;
    for name in PRESET_NAMES {
        let shape = ModelShape::preset(name).unwrap();
        let mut sys = system(36, seed);
        let mut t = MockTrainer::with_shape(36, 600, &shape);
        let cfg = FleetConfig {
            rounds: 4,
            shards: 3,
            max_staleness: 1,
            cohort_size: 6,
            n_rb: 6,
            cohort_strategy: CohortStrategy::PowerGrouping { m: 4 },
            seed,
            ..Default::default()
        };
        let (h, global) =
            fleet::run_with_model(&mut sys, &mut t, &cfg, name).unwrap();
        assert_eq!(h.rounds.len(), 4, "{name}");
        assert_eq!(
            global.as_slice().len(),
            shape.param_count(),
            "{name}: final model must use the preset's arena"
        );
        assert_eq!(global.payload_bytes(), shape.payload_bytes(), "{name}");
        assert!(
            h.final_accuracy() > h.rounds[0].accuracy.min(0.2),
            "{name}: training must improve"
        );
    }
}

#[test]
fn async_staleness_never_exceeds_bound_for_any_seed() {
    check(
        6,
        GenPair(gen_usize(24..60), gen_usize(0..10_000)),
        |&(u, seed)| {
            let mut sys = system(u, seed as u64);
            let mut t = MockTrainer::new(u, 600);
            let max_staleness = 1 + seed % 3;
            let cfg = FleetConfig {
                rounds: 6,
                shards: 3,
                max_staleness,
                cohort_size: 6,
                n_rb: 6,
                seed: seed as u64,
                ..Default::default()
            };
            let h = fleet::run(&mut sys, &mut t, &cfg, "stale").unwrap();
            for r in &h.rounds {
                prop_assert(
                    r.staleness_mean <= max_staleness as f64,
                    &format!(
                        "round {}: mean staleness {} > bound {max_staleness}",
                        r.round, r.staleness_mean
                    ),
                )?;
                prop_assert(
                    r.shards_committed <= 3,
                    "cannot commit more shards than exist",
                )?;
            }
            let commits: usize = h.rounds.iter().map(|r| r.shards_committed).sum();
            prop_assert(commits > 0, "async run must commit something")
        },
    );
}

//! Property tests for the fleet layer: the degenerate-mode equivalence
//! contracts (`shards = 1, regions = 1, max_staleness = 0` ≡ the flat
//! coordinator bit-for-bit; `regions = 1` ≡ the two-level PR-2 fold
//! bit-for-bit), the hierarchical fold's exactness across all three
//! tiers, and shard/region-partition + churn-rebalance invariants (mock
//! backend — no artifacts needed).

use std::collections::HashSet;

use cnc_fl::cnc::optimize::{CohortStrategy, RbStrategy};
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::MockTrainer;
use cnc_fl::fleet::{
    self, fold_regions, ChurnDiff, FleetConfig, FleetTopology, RootAggregator,
    ShardBy, ShardUpdate,
};
use cnc_fl::metrics::RunHistory;
use cnc_fl::model::aggregate::weighted_average;
use cnc_fl::model::params::ModelParams;
use cnc_fl::model::shape::{ModelShape, PRESET_NAMES};
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::runtime::ParallelExecutor;
use cnc_fl::util::propcheck::{check, gen_usize, prop_assert, GenPair};
use cnc_fl::util::rng::Pcg64;

fn system(n: usize, seed: u64) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 2;
    CncSystem::bootstrap(n, 600, 1, PowerProfile::Bimodal, ch, seed)
}

/// Bitwise comparison of the fields both engines fill (compute_wall_s is
/// wall-clock and the shard columns are fleet-only by design).
fn assert_histories_identical(a: &RunHistory, b: &RunHistory) -> Result<(), String> {
    if a.rounds.len() != b.rounds.len() {
        return Err(format!(
            "round counts differ: {} vs {}",
            a.rounds.len(),
            b.rounds.len()
        ));
    }
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        if x.accuracy.to_bits() != y.accuracy.to_bits() {
            return Err(format!(
                "round {}: accuracy {} vs {}",
                x.round, x.accuracy, y.accuracy
            ));
        }
        if x.train_loss.to_bits() != y.train_loss.to_bits() {
            return Err(format!(
                "round {}: loss {} vs {}",
                x.round, x.train_loss, y.train_loss
            ));
        }
        if x.local_delays_s != y.local_delays_s
            || x.tx_delays_s != y.tx_delays_s
            || x.tx_energies_j != y.tx_energies_j
            || x.dropouts != y.dropouts
        {
            return Err(format!("round {}: decision telemetry differs", x.round));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// degenerate mode ≡ flat coordinator
// ---------------------------------------------------------------------------

#[test]
fn one_shard_sync_fleet_equals_traditional_for_any_seed_and_width() {
    check(
        6,
        GenPair(gen_usize(15..40), gen_usize(0..10_000)),
        |&(u, seed)| {
            let cohort = (u / 3).max(2);
            let m = (u / cohort).clamp(1, u);
            for threads in [1usize, 4] {
                let trad = {
                    let mut sys = system(u, seed as u64);
                    let mut t = MockTrainer::new(u, 600);
                    let cfg = TraditionalConfig {
                        rounds: 3,
                        cohort_size: cohort,
                        n_rb: cohort,
                        epoch_local: 2,
                        cohort_strategy: CohortStrategy::PowerGrouping { m },
                        threads,
                        seed: seed as u64,
                        ..Default::default()
                    };
                    traditional::run(&mut sys, &mut t, &cfg, "flat").unwrap()
                };
                let flt = {
                    let mut sys = system(u, seed as u64);
                    let mut t = MockTrainer::new(u, 600);
                    let cfg = FleetConfig {
                        rounds: 3,
                        shards: 1,
                        regions: 1,
                        max_staleness: 0,
                        cohort_size: cohort,
                        n_rb: cohort,
                        epoch_local: 2,
                        cohort_strategy: CohortStrategy::PowerGrouping { m },
                        rb_strategy: RbStrategy::HungarianEnergy,
                        threads,
                        seed: seed as u64,
                        ..Default::default()
                    };
                    fleet::run(&mut sys, &mut t, &cfg, "fleet").unwrap()
                };
                assert_histories_identical(&trad, &flt)
                    .map_err(|e| format!("threads {threads}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn degenerate_mode_holds_for_uniform_cohorts_too() {
    // FedAvg-style strategies go through different decision code paths;
    // the degenerate contract must hold there as well
    let seed = 77u64;
    let trad = {
        let mut sys = system(30, seed);
        let mut t = MockTrainer::new(30, 600);
        let cfg = TraditionalConfig {
            rounds: 4,
            cohort_size: 6,
            n_rb: 8,
            cohort_strategy: CohortStrategy::Uniform,
            rb_strategy: RbStrategy::Random,
            seed,
            ..Default::default()
        };
        traditional::run(&mut sys, &mut t, &cfg, "flat").unwrap()
    };
    let flt = {
        let mut sys = system(30, seed);
        let mut t = MockTrainer::new(30, 600);
        let cfg = FleetConfig {
            rounds: 4,
            shards: 1,
            regions: 1,
            max_staleness: 0,
            cohort_size: 6,
            n_rb: 8,
            cohort_strategy: CohortStrategy::Uniform,
            rb_strategy: RbStrategy::Random,
            seed,
            ..Default::default()
        };
        fleet::run(&mut sys, &mut t, &cfg, "fleet").unwrap()
    };
    assert_histories_identical(&trad, &flt).unwrap();
}

// ---------------------------------------------------------------------------
// regions = 1 ≡ the PR-2 two-level fold, bit-for-bit, for every preset
// ---------------------------------------------------------------------------

#[test]
fn one_region_fold_is_bitwise_the_two_level_fold_for_all_presets() {
    // the engine commits through `fold_regions`; with one region it must
    // perform exactly the op sequence the PR-2 root did (offer in shard
    // order with decay^staleness weighting) — pinned bitwise for every
    // model preset, random staleness patterns, serial and parallel
    // executors
    check(
        9,
        GenPair(gen_usize(2..10), gen_usize(0..100_000)),
        |&(n, seed)| {
            let preset = PRESET_NAMES[seed % PRESET_NAMES.len()];
            let shape = ModelShape::preset(preset).unwrap();
            let mut rng = Pcg64::seed_from(seed as u64 ^ 0xAB1E);
            let round = 6usize;
            let max_staleness = seed % 3;
            let decay = 0.5 + 0.5 * (seed % 2) as f64; // 0.5 or 1.0
            let updates: Vec<ShardUpdate> = (0..n)
                .map(|s| {
                    // some round tags exceed the bound → rejected on
                    // both paths
                    let tag = round - rng.below(4) as usize;
                    let mut u = ShardUpdate::new(&shape, s, tag);
                    let mut m = ModelParams::zeros(&shape);
                    for v in m.as_mut_slice() {
                        *v = rng.normal_scaled(0.0, 0.1) as f32;
                    }
                    u.push(&m, 100 + rng.below(500) as usize);
                    u
                })
                .collect();

            // PR-2 path: offer every shard update to the root directly
            let mut two = RootAggregator::new(&shape, max_staleness, decay);
            for u in &updates {
                two.offer(u, round);
            }

            // three-level path, one region
            let due: Vec<Vec<&ShardUpdate>> = vec![updates.iter().collect()];
            for threads in [1usize, 4] {
                let ex = ParallelExecutor::new(threads);
                let (three, _) =
                    fold_regions(&shape, &due, round, max_staleness, decay, &ex)
                        .map_err(|e| format!("fold: {e}"))?;
                prop_assert(
                    three.accepted() == two.accepted()
                        && three.rejected() == two.rejected()
                        && three.mean_staleness() == two.mean_staleness(),
                    &format!("{preset}: counters diverged (threads {threads})"),
                )?;
                if two.accepted() == 0 {
                    continue;
                }
                let a = two.clone().finish().map_err(|e| e.to_string())?;
                let b = three.finish().map_err(|e| e.to_string())?;
                let bitwise = a
                    .as_slice()
                    .iter()
                    .zip(b.as_slice())
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                prop_assert(
                    bitwise,
                    &format!(
                        "{preset}: one-region fold drifted from the two-level \
                         fold (threads {threads})"
                    ),
                )?;
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// hierarchical fold ≡ flat weighted average (0 ULP on integer inputs)
// ---------------------------------------------------------------------------

fn integer_params(shape: &std::sync::Arc<ModelShape>, seed: u64) -> ModelParams {
    // small integer values: every partial sum stays exactly representable
    // in f32 (well under 2^24), so regrouping cannot round
    let mut rng = Pcg64::seed_from(seed);
    let mut m = ModelParams::zeros(shape);
    for v in m.as_mut_slice() {
        *v = rng.range_i64(-8, 8) as f32;
    }
    m
}

#[test]
fn hierarchical_fold_is_0ulp_equal_to_flat_on_integer_weights() {
    check(
        15,
        GenPair(gen_usize(2..12), gen_usize(0..1_000_000)),
        |&(n, seed)| {
            let shape = ModelShape::paper();
            let mut rng = Pcg64::seed_from(seed as u64 ^ 0x51A6);
            let updates: Vec<(ModelParams, usize)> = (0..n)
                .map(|i| {
                    let m = integer_params(&shape, seed as u64 * 131 + i as u64);
                    let w = rng.below(7) as usize + 1;
                    (m, w)
                })
                .collect();
            let flat = weighted_average(&updates)
                .map_err(|e| format!("weighted_average: {e}"))?;

            // random contiguous two-level grouping of the same updates in
            // the same order
            let cuts = rng.below(n as u64 - 1) as usize + 1; // 1..n shards
            let mut root = RootAggregator::new(&shape, 0, 1.0);
            let mut idx = 0usize;
            for shard in 0..cuts {
                let hi = if shard + 1 == cuts {
                    n
                } else {
                    (idx + (n - idx) / (cuts - shard)).max(idx + 1)
                };
                let mut upd = ShardUpdate::new(&shape, shard, 0);
                for (m, w) in &updates[idx..hi] {
                    upd.push(m, *w);
                }
                idx = hi;
                root.offer(&upd, 0);
            }
            let hier = root.finish().map_err(|e| format!("finish: {e}"))?;
            let bitwise_equal = flat
                .as_slice()
                .iter()
                .zip(hier.as_slice())
                .all(|(a, b)| a.to_bits() == b.to_bits());
            prop_assert(bitwise_equal, "two-level fold drifted from flat fold")
        },
    );
}

#[test]
fn three_level_region_fold_is_0ulp_equal_to_flat_on_integer_inputs() {
    // fixed cohort of integer-valued updates folded client → shard →
    // region → root must regroup exactly to the flat Eq 1 average
    let shape = ModelShape::paper();
    let updates: Vec<(ModelParams, usize)> = (0..12)
        .map(|i| (integer_params(&shape, 0xF00 + i as u64), (i as usize % 5) + 1))
        .collect();
    let flat = weighted_average(&updates).unwrap();

    // 6 shards of 2 updates, grouped into 3 regions of 2 shards
    let shard_updates: Vec<ShardUpdate> = (0..6)
        .map(|s| {
            let mut u = ShardUpdate::new(&shape, s, 0);
            u.push(&updates[2 * s].0, updates[2 * s].1);
            u.push(&updates[2 * s + 1].0, updates[2 * s + 1].1);
            u
        })
        .collect();
    let due: Vec<Vec<&ShardUpdate>> = (0..3)
        .map(|r| vec![&shard_updates[2 * r], &shard_updates[2 * r + 1]])
        .collect();
    for threads in [1usize, 3] {
        let ex = ParallelExecutor::new(threads);
        let (root, accepts) = fold_regions(&shape, &due, 0, 0, 1.0, &ex).unwrap();
        assert_eq!(root.accepted(), 6);
        assert_eq!(root.regions_merged(), 3);
        assert_eq!(accepts.iter().map(Vec::len).sum::<usize>(), 6);
        let hier = root.finish().unwrap();
        assert!(
            flat.as_slice()
                .iter()
                .zip(hier.as_slice())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
            "three-level fold drifted from flat fold (threads {threads})"
        );
    }
}

// ---------------------------------------------------------------------------
// shard/region-partition + rebalance invariants
// ---------------------------------------------------------------------------

#[test]
fn shards_always_partition_and_views_always_match() {
    check(
        20,
        GenPair(gen_usize(4..120), gen_usize(0..10_000)),
        |&(u, seed)| {
            let sys = system(u, seed as u64);
            let k = (u / 4).max(1).min(9);
            let r = (k / 2).max(1);
            for by in [ShardBy::Locality, ShardBy::Power] {
                let f = FleetTopology::build(&sys.pool, k, by, r, by)
                    .map_err(|e| format!("build: {e}"))?;
                let mut all: Vec<usize> =
                    f.shards.iter().flat_map(|s| s.members.clone()).collect();
                all.sort_unstable();
                prop_assert(
                    all == (0..u).collect::<Vec<_>>(),
                    "shards must partition the fleet",
                )?;
                let mut shard_ids: Vec<usize> =
                    f.regions.iter().flat_map(|rg| rg.shards.clone()).collect();
                shard_ids.sort_unstable();
                prop_assert(
                    shard_ids == (0..k).collect::<Vec<_>>(),
                    "regions must partition the shards",
                )?;
                for s in &f.shards {
                    let sorted = s.members.windows(2).all(|w| w[0] < w[1]);
                    prop_assert(sorted, "members must be id-sorted")?;
                    let sp = f.shard_pool(s.id);
                    for (local, &c) in s.members.iter().enumerate() {
                        prop_assert(
                            sp.fleet.delays_s[local] == sys.pool.fleet.delays_s[c]
                                && sp.fleet.data_sizes[local]
                                    == sys.pool.fleet.data_sizes[c],
                            "shard view must mirror the global pool",
                        )?;
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rebalance_invariants_hold_under_injected_churn() {
    // (b) of the region-tier acceptance: across repeated churn events the
    // client set is preserved modulo the reported diff, no shard is ever
    // empty, stable ids stay unique and survivors keep theirs
    check(
        8,
        GenPair(gen_usize(30..90), gen_usize(0..10_000)),
        |&(u, seed)| {
            let mut sys = system(u, seed as u64);
            let k = (u / 8).max(2);
            let r = (k / 2).max(1);
            let mut topo = FleetTopology::build(
                &sys.pool,
                k,
                ShardBy::Power,
                r,
                ShardBy::Locality,
            )
            .map_err(|e| format!("build: {e}"))?;
            let rate = 0.1 + (seed % 3) as f64 * 0.1;
            for event in 0..3u64 {
                let before: HashSet<u64> =
                    topo.client_ids.iter().copied().collect();
                let rng = Pcg64::new(seed as u64, event);
                let diff = topo
                    .churn(&mut sys.pool, rate, &rng)
                    .map_err(|e| format!("churn: {e}"))?;
                let expect = ((rate * u as f64).round() as usize).min(u);
                prop_assert(
                    diff.joined == expect && diff.left == expect,
                    &format!("diff {diff:?} != expected churn {expect}"),
                )?;
                let after: HashSet<u64> =
                    topo.client_ids.iter().copied().collect();
                prop_assert(after.len() == u, "stable ids must stay unique")?;
                prop_assert(
                    before.intersection(&after).count() == u - diff.left,
                    "survivors must keep their ids",
                )?;
                // the partition stays exact and nonempty after rebuild
                let mut all: Vec<usize> = topo
                    .shards
                    .iter()
                    .flat_map(|s| s.members.clone())
                    .collect();
                all.sort_unstable();
                prop_assert(
                    all == (0..u).collect::<Vec<_>>(),
                    "churned shards must still partition the fleet",
                )?;
                prop_assert(
                    topo.shards.iter().all(|s| !s.is_empty()),
                    "churn must never leave an empty shard",
                )?;
                prop_assert(
                    topo.regions.iter().all(|rg| !rg.shards.is_empty()),
                    "churn must never leave an empty region",
                )?;
                prop_assert(
                    diff.moved <= u - diff.left,
                    "moved counts only survivors",
                )?;
            }
            // an untouched pool rebalances to the identical assignment
            let diff = topo
                .rebalance(&sys.pool)
                .map_err(|e| format!("rebalance: {e}"))?;
            prop_assert(
                diff == ChurnDiff::default(),
                "no-op rebalance must report no changes",
            )
        },
    );
}

// ---------------------------------------------------------------------------
// model-size scenario axis: one binary, several arenas
// ---------------------------------------------------------------------------

#[test]
fn fleet_engine_runs_every_shape_preset_without_recompiling() {
    // the dynamic arena's acceptance bar: full sharded/async fleet rounds
    // over all three model sizes in one process, each training the arena
    // its shape declares — now through the region tier
    let seed = 5u64;
    for name in PRESET_NAMES {
        let shape = ModelShape::preset(name).unwrap();
        let mut sys = system(36, seed);
        let mut t = MockTrainer::with_shape(36, 600, &shape);
        let cfg = FleetConfig {
            rounds: 4,
            shards: 3,
            regions: 2,
            max_staleness: 1,
            cohort_size: 6,
            n_rb: 6,
            cohort_strategy: CohortStrategy::PowerGrouping { m: 4 },
            seed,
            ..Default::default()
        };
        let (h, global) =
            fleet::run_with_model(&mut sys, &mut t, &cfg, name).unwrap();
        assert_eq!(h.rounds.len(), 4, "{name}");
        assert_eq!(
            global.as_slice().len(),
            shape.param_count(),
            "{name}: final model must use the preset's arena"
        );
        assert_eq!(global.payload_bytes(), shape.payload_bytes(), "{name}");
        assert!(
            h.rounds.iter().all(|r| r.regions_committed <= 2),
            "{name}: more region commits than regions"
        );
        assert!(
            h.final_accuracy() > h.rounds[0].accuracy.min(0.2),
            "{name}: training must improve"
        );
    }
}

// ---------------------------------------------------------------------------
// discrete-event driver ≡ loop driver, bitwise, with waves degenerate
// ---------------------------------------------------------------------------

/// Three topology shapes spanning the engine's regimes: one-shard
/// synchronous (the flat-coordinator degenerate corner), multi-shard
/// async with bounded staleness, and the region tier under injected
/// churn. With `waves: Always` (the default) the event driver must be a
/// pure re-sequencing of the loop driver — same phases, same RNG
/// streams, same fold order — so both CSVs and both final models are
/// bit-identical.
fn event_loop_shapes() -> Vec<(usize, FleetConfig)> {
    vec![
        (
            30,
            FleetConfig {
                rounds: 4,
                shards: 1,
                regions: 1,
                max_staleness: 0,
                cohort_size: 6,
                n_rb: 6,
                cohort_strategy: CohortStrategy::PowerGrouping { m: 5 },
                seed: 11,
                ..Default::default()
            },
        ),
        (
            36,
            FleetConfig {
                rounds: 5,
                shards: 3,
                regions: 1,
                max_staleness: 2,
                cohort_size: 6,
                n_rb: 6,
                seed: 23,
                ..Default::default()
            },
        ),
        (
            40,
            FleetConfig {
                rounds: 4,
                shards: 4,
                regions: 2,
                max_staleness: 1,
                cohort_size: 8,
                n_rb: 8,
                churn_every: 2,
                churn_rate: 0.1,
                seed: 37,
                ..Default::default()
            },
        ),
    ]
}

#[test]
fn event_driver_is_bitwise_the_loop_driver_across_shapes_and_threads() {
    for (u, base) in event_loop_shapes() {
        for threads in [1usize, 4] {
            let mut cfg = base.clone();
            cfg.threads = threads;
            let (loop_h, loop_m) = {
                let mut sys = system(u, cfg.seed);
                let mut t = MockTrainer::new(u, 600);
                fleet::run_with_model(&mut sys, &mut t, &cfg, "loop").unwrap()
            };
            let (ev_h, ev_m) = {
                let mut sys = system(u, cfg.seed);
                let mut t = MockTrainer::new(u, 600);
                fleet::event::run_with_model(&mut sys, &mut t, &cfg, "event")
                    .unwrap()
            };
            assert_eq!(
                loop_h.to_csv().to_string(),
                ev_h.to_csv().to_string(),
                "shards {} threads {threads}: CSVs diverged",
                cfg.shards
            );
            assert_eq!(
                loop_m.max_abs_diff(&ev_m),
                0.0,
                "shards {} threads {threads}: final models diverged",
                cfg.shards
            );
        }
    }
}

#[test]
fn event_trace_is_identical_across_thread_counts() {
    // the priority-queue clock is the only event ordering — worker-pool
    // scheduling must never leak into the trace or the outputs
    let (u, base) = event_loop_shapes().remove(2);
    let mut runs = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut cfg = base.clone();
        cfg.threads = threads;
        let mut sys = system(u, cfg.seed);
        let mut t = MockTrainer::new(u, 600);
        let (h, m, trace) =
            fleet::event::run_recorded(&mut sys, &mut t, &cfg, "trace").unwrap();
        runs.push((h.to_csv().to_string(), m, trace));
    }
    // 5 events per round, every round closed
    assert_eq!(runs[0].2.len(), 5 * base.rounds);
    for r in &runs[1..] {
        assert_eq!(runs[0].0, r.0, "CSV must not depend on thread count");
        assert_eq!(runs[0].1.max_abs_diff(&r.1), 0.0);
        assert_eq!(runs[0].2, r.2, "event trace must not depend on threads");
    }
}

#[test]
fn async_staleness_never_exceeds_bound_for_any_seed() {
    check(
        6,
        GenPair(gen_usize(24..60), gen_usize(0..10_000)),
        |&(u, seed)| {
            let mut sys = system(u, seed as u64);
            let mut t = MockTrainer::new(u, 600);
            let max_staleness = 1 + seed % 3;
            let cfg = FleetConfig {
                rounds: 6,
                shards: 3,
                regions: 2,
                max_staleness,
                cohort_size: 6,
                n_rb: 6,
                seed: seed as u64,
                ..Default::default()
            };
            let h = fleet::run(&mut sys, &mut t, &cfg, "stale").unwrap();
            for r in &h.rounds {
                prop_assert(
                    r.staleness_mean <= max_staleness as f64,
                    &format!(
                        "round {}: mean staleness {} > bound {max_staleness}",
                        r.round, r.staleness_mean
                    ),
                )?;
                prop_assert(
                    r.shards_committed <= 3,
                    "cannot commit more shards than exist",
                )?;
                prop_assert(
                    r.regions_committed <= 2,
                    "cannot commit more regions than exist",
                )?;
            }
            let commits: usize = h.rounds.iter().map(|r| r.shards_committed).sum();
            prop_assert(commits > 0, "async run must commit something")
        },
    );
}

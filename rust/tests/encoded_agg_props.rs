//! Equivalence contract of the encoded-domain aggregation stack
//! (`model::encoded`) against the dense seed path:
//!
//! * raw codec: the encoded fold is **bit-identical** to the dense
//!   [`Aggregator`] — flat, hierarchical, serial and parallel;
//! * quant8 / top-k: the encoded fold tracks decode-then-fold within a
//!   stated absolute tolerance (both paths fold the *same* lossy wire
//!   payload, so the codec's loss itself cancels out);
//! * the `UpdateGuard` rejects identically whether admission runs on
//!   the decoded update or on the encoded form, under byzantine
//!   weather, and the full engine stays guarded on the encoded path.

use std::sync::Arc;

use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::trainer::MockTrainer;
use cnc_fl::fleet::async_round::{run_with_model, FleetConfig};
use cnc_fl::fleet::hierarchy::{fold_regions_guarded, ShardUpdate};
use cnc_fl::fleet::weather::{poison, GuardPolicy, UpdateGuard, WeatherSpec};
use cnc_fl::model::aggregate::Aggregator;
use cnc_fl::model::compress::PayloadCodec;
use cnc_fl::model::encoded::EncodedAggregator;
use cnc_fl::model::params::ModelParams;
use cnc_fl::model::shape::{ModelShape, PRESET_NAMES};
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::runtime::ParallelExecutor;
use cnc_fl::util::rng::Pcg64;

/// Absolute tolerance for the lossy-codec contract (documented in
/// `model::encoded`): both paths fold identical payloads, so the only
/// divergence is f32 summation order, orders of magnitude below this.
const LOSSY_TOL: f32 = 1e-4;

fn random_update(shape: &Arc<ModelShape>, seed: u64) -> ModelParams {
    let mut rng = Pcg64::seed_from(seed);
    let mut m = ModelParams::zeros(shape);
    for v in m.as_mut_slice() {
        *v = rng.normal_scaled(0.0, 0.05) as f32;
    }
    m
}

fn bitwise_eq(a: &ModelParams, b: &ModelParams) -> bool {
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .all(|(x, y)| x.to_bits() == y.to_bits())
}

fn max_abs_diff(a: &ModelParams, b: &ModelParams) -> f32 {
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn raw_encoded_fold_is_bitwise_the_dense_aggregator_on_every_preset() {
    for preset in PRESET_NAMES {
        let shape = ModelShape::preset(preset).unwrap();
        let updates: Vec<(ModelParams, usize)> = (0..12)
            .map(|i| (random_update(&shape, i), 100 + 97 * i as usize))
            .collect();
        let mut dense = Aggregator::new(&shape);
        let mut encoded = EncodedAggregator::for_codec(&shape, PayloadCodec::Raw);
        for (m, w) in &updates {
            dense.push(m, *w);
            let enc = PayloadCodec::Raw.encode(m.clone()).unwrap();
            encoded.push_encoded(&enc, *w);
        }
        assert_eq!(dense.count(), encoded.count());
        assert_eq!(dense.total_weight(), encoded.total_weight());
        let (a, b) = (dense.finish().unwrap(), encoded.finish().unwrap());
        assert!(bitwise_eq(&a, &b), "{preset}: raw encoded fold drifted");
    }
}

#[test]
fn raw_hierarchical_fold_matches_flat_bitwise_across_executor_widths() {
    for preset in PRESET_NAMES {
        let shape = ModelShape::preset(preset).unwrap();
        let updates: Vec<(ModelParams, usize)> = (0..9)
            .map(|i| (random_update(&shape, 1000 + i), 50 + 31 * i as usize))
            .collect();
        // flat dense fold — the seed semantics
        let mut flat = Aggregator::new(&shape);
        for (m, w) in &updates {
            flat.push(m, *w);
        }
        let flat = flat.finish().unwrap();
        // one shard, one region: merge-into-empty is a bitwise copy, so
        // every executor width must reproduce the flat fold exactly
        let mut shard = ShardUpdate::for_codec(&shape, PayloadCodec::Raw, 0, 3);
        for (m, w) in &updates {
            let enc = PayloadCodec::Raw.encode(m.clone()).unwrap();
            shard.push_encoded(&enc, *w);
        }
        for threads in [1, 2, 4] {
            let ex = ParallelExecutor::new(threads);
            let due: Vec<Vec<&ShardUpdate>> = vec![vec![&shard]];
            let (root, _) =
                fold_regions_guarded(&shape, &due, 3, 0, 1.0, 0.0, &ex).unwrap();
            let hier = root.finish().unwrap();
            assert!(
                bitwise_eq(&flat, &hier),
                "{preset}: single-shard hierarchy drifted at {threads} threads"
            );
        }
        // three shards over two regions: widths must agree bit-for-bit
        // with each other (slot-ordered reduction)
        let shards: Vec<ShardUpdate> = (0..3)
            .map(|s| {
                let mut u = ShardUpdate::for_codec(&shape, PayloadCodec::Raw, s, 3);
                for (m, w) in updates.iter().skip(s * 3).take(3) {
                    let enc = PayloadCodec::Raw.encode(m.clone()).unwrap();
                    u.push_encoded(&enc, *w);
                }
                u
            })
            .collect();
        let due: Vec<Vec<&ShardUpdate>> =
            vec![shards[0..2].iter().collect(), shards[2..3].iter().collect()];
        let serial = {
            let ex = ParallelExecutor::new(1);
            let (root, _) =
                fold_regions_guarded(&shape, &due, 3, 0, 1.0, 0.0, &ex).unwrap();
            root.finish().unwrap()
        };
        for threads in [2, 4] {
            let ex = ParallelExecutor::new(threads);
            let (root, _) =
                fold_regions_guarded(&shape, &due, 3, 0, 1.0, 0.0, &ex).unwrap();
            let m = root.finish().unwrap();
            assert!(
                bitwise_eq(&serial, &m),
                "{preset}: parallel region fold drifted at {threads} threads"
            );
        }
    }
}

#[test]
fn lossy_encoded_fold_tracks_decode_then_fold_within_tolerance() {
    let codecs = [
        PayloadCodec::Quant8,
        PayloadCodec::TopK { keep_frac: 0.25 },
        PayloadCodec::TopK { keep_frac: 0.05 },
    ];
    for preset in PRESET_NAMES {
        let shape = ModelShape::preset(preset).unwrap();
        for codec in codecs {
            let mut baseline = Aggregator::new(&shape);
            let mut encoded = EncodedAggregator::for_codec(&shape, codec);
            for i in 0..10 {
                let m = random_update(&shape, 2000 + i);
                let w = 200 + 57 * i as usize;
                let enc = codec.encode(m).unwrap();
                baseline.push(&enc.decode(), w);
                encoded.push_encoded(&enc, w);
            }
            let (a, b) = (baseline.finish().unwrap(), encoded.finish().unwrap());
            let diff = max_abs_diff(&a, &b);
            assert!(
                diff < LOSSY_TOL,
                "{preset}/{}: encoded fold diverged by {diff}",
                codec.label()
            );
        }
    }
}

#[test]
fn guard_rejections_are_identical_on_the_dense_and_encoded_paths() {
    // replay the engine's byzantine wire point on both fold paths with
    // the same poison draws: the rejection ledger must not depend on
    // whether admission saw the decoded arena or the encoded payload
    let shape = ModelShape::preset("mlp-small").unwrap();
    let guard = UpdateGuard::new(&GuardPolicy::default());
    let codecs = [
        PayloadCodec::Raw,
        PayloadCodec::Quant8,
        PayloadCodec::TopK { keep_frac: 0.1 },
    ];
    for codec in codecs {
        let mut draw_rng = Pcg64::seed_from(77);
        let mut dense_rejects = 0usize;
        let mut encoded_rejects = 0usize;
        let mut dense = Aggregator::new(&shape);
        let mut encoded = EncodedAggregator::for_codec(&shape, codec);
        for i in 0..40 {
            let enc = codec.encode(random_update(&shape, 3000 + i)).unwrap();
            let poisoned = (draw_rng.next_f64() < 0.4)
                .then(|| poison(&enc.decode(), draw_rng.below(3)));
            match &poisoned {
                Some(p) => {
                    // poisoned slots take the dense path in both folds
                    if guard.admit(p) {
                        dense.push(p, 600);
                        encoded.push(p, 600);
                    } else {
                        dense_rejects += 1;
                        encoded_rejects += 1;
                    }
                }
                None => {
                    if guard.admit(&enc.decode()) {
                        dense.push(&enc.decode(), 600);
                    } else {
                        dense_rejects += 1;
                    }
                    if guard.admit_encoded(&enc) {
                        encoded.push_encoded(&enc, 600);
                    } else {
                        encoded_rejects += 1;
                    }
                }
            }
        }
        assert!(dense_rejects > 0, "{}: no poison fired", codec.label());
        assert_eq!(
            dense_rejects,
            encoded_rejects,
            "{}: guard verdicts diverged between paths",
            codec.label()
        );
        assert_eq!(dense.count(), encoded.count());
        let (a, b) = (dense.finish().unwrap(), encoded.finish().unwrap());
        assert!(a.as_slice().iter().all(|v| v.is_finite()));
        if codec.is_raw() {
            assert!(bitwise_eq(&a, &b), "raw paths must agree bitwise");
        } else {
            let diff = max_abs_diff(&a, &b);
            assert!(diff < LOSSY_TOL, "{}: diverged by {diff}", codec.label());
        }
    }
}

#[test]
fn byzantine_engine_on_the_encoded_path_stays_guarded_and_deterministic() {
    let run_width = |threads: usize| {
        let ch = ChannelParams {
            fading_samples: 4,
            ..Default::default()
        };
        let mut sys = CncSystem::bootstrap(30, 600, 1, PowerProfile::Bimodal, ch, 21);
        let mut trainer = MockTrainer::new(30, 600);
        let mut cfg = FleetConfig {
            rounds: 4,
            shards: 2,
            weather: WeatherSpec::Byzantine { frac: 0.5 },
            threads,
            ..Default::default()
        };
        cfg.transport.codec = PayloadCodec::Quant8;
        run_with_model(&mut sys, &mut trainer, &cfg, "byz-enc").unwrap()
    };
    let (serial, global) = run_width(1);
    let rejected: usize = serial.rounds.iter().map(|r| r.rejected_updates).sum();
    assert!(rejected > 0, "byzantine weather must reject something");
    assert!(global.as_slice().iter().all(|v| v.is_finite()));
    for r in &serial.rounds {
        assert!(r.accuracy.is_finite());
    }
    // the encoded shard fold preserves the engine's width-independence
    for threads in [2, 4] {
        let (parallel, pglobal) = run_width(threads);
        for (a, b) in serial.rounds.iter().zip(&parallel.rounds) {
            assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.rejected_updates, b.rejected_updates);
        }
        assert!(bitwise_eq(&global, &pglobal), "{threads} threads drifted");
    }
}

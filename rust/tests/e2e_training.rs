//! End-to-end integration: full federated training through all three
//! layers (Rust CNC coordinator → PJRT → AOT-lowered JAX model → Pallas
//! kernels) on the synthetic workload.
//!
//! This is the "does the whole system actually learn?" test — a short
//! Pr1-style run whose accuracy must climb well above chance, plus the
//! CNC-vs-FedAvg comparisons on the real compute path.
//!
//! Skips when artifacts are missing (`make artifacts`).

use std::path::PathBuf;

use cnc_fl::coordinator::{p2p, traditional, PjrtTrainer};
use cnc_fl::cnc::optimize::{CohortStrategy, RbStrategy};
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::p2p::P2pConfig;
use cnc_fl::coordinator::traditional::TraditionalConfig;
use cnc_fl::data::{Partition, Split, SynthSpec};
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::netsim::topology::TopologyGen;
use cnc_fl::runtime::{ArtifactStore, Engine};
use cnc_fl::util::rng::Pcg64;

fn trainer(num_clients: usize, split: Split) -> Option<PjrtTrainer> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let engine = Engine::new(ArtifactStore::load(&dir).unwrap()).unwrap();
    let partition = Partition::new(num_clients, split, 0);
    Some(PjrtTrainer::new(engine, partition, SynthSpec::default(), 0.01, 0).unwrap())
}

fn system(num_clients: usize, epoch_local: usize) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 16;
    CncSystem::bootstrap(
        num_clients,
        cnc_fl::data::synth::TRAIN_TOTAL / num_clients,
        epoch_local,
        PowerProfile::Bimodal,
        ch,
        0,
    )
}

#[test]
fn traditional_cnc_learns_iid() {
    let Some(mut t) = trainer(100, Split::Iid) else { return };
    let mut sys = system(100, 1);
    let cfg = TraditionalConfig {
        rounds: 15,
        eval_every: 5,
        ..Default::default()
    };
    let h = traditional::run(&mut sys, &mut t, &cfg, "e2e/iid").unwrap();
    assert_eq!(h.rounds.len(), 15);
    let acc = h.final_accuracy();
    assert!(acc > 0.5, "15 rounds should clear 50% on IID, got {acc}");
    // training loss must fall
    assert!(h.rounds.last().unwrap().train_loss < h.rounds[0].train_loss);
}

#[test]
fn traditional_cnc_learns_non_iid() {
    let Some(mut t) = trainer(100, Split::NonIid) else { return };
    let mut sys = system(100, 1);
    let cfg = TraditionalConfig {
        rounds: 15,
        eval_every: 5,
        ..Default::default()
    };
    let h = traditional::run(&mut sys, &mut t, &cfg, "e2e/noniid").unwrap();
    let acc = h.final_accuracy();
    // Non-IID converges slower (paper Fig 4) but must beat chance
    assert!(acc > 0.25, "non-IID after 15 rounds: {acc}");
}

#[test]
fn p2p_chain_learns() {
    let Some(mut t) = trainer(20, Split::Iid) else { return };
    let mut sys = system(20, 1);
    let mut rng = Pcg64::seed_from(3);
    let g = TopologyGen::full(20, 1.0, 10.0, &mut rng);
    let cfg = P2pConfig {
        rounds: 3,
        ..Default::default()
    };
    let h = p2p::run(&mut sys, &mut t, &g, &cfg, "e2e/p2p").unwrap();
    // every client trains each round → 3 rounds of 20 chains is plenty
    let acc = h.final_accuracy();
    assert!(acc > 0.6, "P2P after 3 full-fleet rounds: {acc}");
    assert!(h.accuracies().windows(2).all(|w| w[1] >= w[0] - 0.05));
}

#[test]
fn cnc_and_fedavg_reach_similar_accuracy_but_cnc_cheaper() {
    let Some(mut t1) = trainer(100, Split::Iid) else { return };
    let base = TraditionalConfig {
        rounds: 8,
        eval_every: 4,
        ..Default::default()
    };
    let mut sys1 = system(100, 1);
    let h_cnc = traditional::run(&mut sys1, &mut t1, &base, "cnc").unwrap();

    let mut t2 = trainer(100, Split::Iid).unwrap();
    let mut sys2 = system(100, 1);
    let mut avg = base.clone();
    avg.cohort_strategy = CohortStrategy::Uniform;
    avg.rb_strategy = RbStrategy::Random;
    let h_avg = traditional::run(&mut sys2, &mut t2, &avg, "fedavg").unwrap();

    // both learn
    assert!(h_cnc.final_accuracy() > 0.35);
    assert!(h_avg.final_accuracy() > 0.35);
    // CNC pays less for transmission (Eq 5 optimum ≤ random)
    let e_cnc: f64 = h_cnc.rounds.iter().map(|r| r.tx_energy_round_j()).sum();
    let e_avg: f64 = h_avg.rounds.iter().map(|r| r.tx_energy_round_j()).sum();
    assert!(e_cnc < e_avg, "cnc {e_cnc} !< fedavg {e_avg}");
    // and balances local delay (mean per-round diff smaller)
    let d = |h: &cnc_fl::metrics::RunHistory| {
        let v = h.delay_diffs();
        v.iter().sum::<f64>() / v.len() as f64
    };
    assert!(d(&h_cnc) < d(&h_avg));
}

#[test]
fn local_epochs_scale_compute_not_crash() {
    let Some(mut t) = trainer(100, Split::Iid) else { return };
    let mut sys = system(100, 5);
    let cfg = TraditionalConfig {
        rounds: 2,
        cohort_size: 5,
        n_rb: 5,
        epoch_local: 5, // Pr2-style
        cohort_strategy: CohortStrategy::PowerGrouping { m: 20 },
        rb_strategy: RbStrategy::BottleneckDelay,
        ..Default::default()
    };
    let h = traditional::run(&mut sys, &mut t, &cfg, "e2e/5ep").unwrap();
    assert_eq!(h.rounds.len(), 2);
    // 5 local epochs → local delays 5× the 1-epoch Eq 8 values
    assert!(h.rounds[0].local_delay_round_s() > 5.0);
}

//! Observability-plane contracts: the disabled/enabled observer must
//! never change engine outputs (CSV bitwise identity, tracing on or
//! off), the JSONL stream must be valid line-JSON with the promised
//! event shape, bus evictions must reach the stream, and the mock
//! path must populate `compute_wall_s` from the train span.

use cnc_fl::cnc::announce::AnnouncementBus;
use cnc_fl::cnc::optimize::CohortStrategy;
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::MockTrainer;
use cnc_fl::fleet::{self, FleetConfig};
use cnc_fl::model::shape::ModelShape;
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::obs::{Observer, TraceSink, PHASES};
use cnc_fl::util::json::Json;

fn system(n: usize) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 2;
    CncSystem::bootstrap(n, 600, 1, PowerProfile::Bimodal, ch, 0)
}

fn fleet_cfg(rounds: usize, shards: usize, threads: usize) -> FleetConfig {
    FleetConfig {
        rounds,
        shards,
        max_staleness: 1,
        cohort_size: 8,
        n_rb: 8,
        cohort_strategy: CohortStrategy::PowerGrouping { m: 5 },
        threads,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------------
// trace off (and on) ⇒ engine outputs bitwise identical
// ---------------------------------------------------------------------------

#[test]
fn fleet_csv_is_bitwise_identical_with_tracing_on_or_off() {
    // the tracer only reads clocks and the sink only writes its own
    // stream; neither may leak into the engine's outputs — pinned for
    // three shape presets, serial and parallel
    for name in ["mlp-small", "mlp-784", "mlp-wide"] {
        let shape = ModelShape::preset(name).unwrap();
        for threads in [1usize, 4] {
            let run_one = |obs: &mut Observer| {
                let mut s = system(40);
                let mut t = MockTrainer::with_shape(40, 600, &shape);
                let cfg = fleet_cfg(4, 4, threads);
                fleet::run_traced(&mut s, &mut t, &cfg, "obs", obs)
                    .unwrap()
                    .to_csv()
                    .to_string()
            };
            let plain = run_one(&mut Observer::disabled());
            let enabled = run_one(&mut Observer::enabled());
            let sunk =
                run_one(&mut Observer::with_sink(TraceSink::in_memory()));
            assert_eq!(plain, enabled, "{name} t{threads}: enabled differs");
            assert_eq!(plain, sunk, "{name} t{threads}: sink differs");
        }
    }
}

#[test]
fn traditional_csv_is_bitwise_identical_with_tracing_on_or_off() {
    let run_one = |obs: &mut Observer| {
        let mut s = system(20);
        let mut t = MockTrainer::new(20, 600);
        let cfg = TraditionalConfig {
            rounds: 3,
            cohort_size: 6,
            n_rb: 6,
            ..Default::default()
        };
        traditional::run_traced(&mut s, &mut t, &cfg, "obs", obs)
            .unwrap()
            .to_csv()
            .to_string()
    };
    let plain = run_one(&mut Observer::disabled());
    let sunk = run_one(&mut Observer::with_sink(TraceSink::in_memory()));
    assert_eq!(plain, sunk);
}

// ---------------------------------------------------------------------------
// the JSONL stream: parseable, with the promised event counts
// ---------------------------------------------------------------------------

#[test]
fn fleet_trace_stream_round_trips_as_line_json() {
    let rounds = 4usize;
    let mut s = system(40);
    let mut t = MockTrainer::new(40, 600);
    let cfg = fleet_cfg(rounds, 4, 1);
    let mut obs = Observer::with_sink(TraceSink::in_memory());
    fleet::run_traced(&mut s, &mut t, &cfg, "trace", &mut obs).unwrap();
    let text = obs.sink_buffer().unwrap();

    let mut phase_events = 0usize;
    let mut round_events = 0usize;
    let mut run_start = 0usize;
    let mut run_end = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| {
            panic!("unparseable trace line `{line}`: {e}")
        });
        match j.get("t").unwrap().as_str().unwrap() {
            "phase" => {
                phase_events += 1;
                assert!(j.get("round").is_some(), "{line}");
                let name = j.get("phase").unwrap().as_str().unwrap();
                assert!(
                    PHASES.iter().any(|p| p.name() == name),
                    "unknown phase `{name}`"
                );
                assert!(j.get("dur_s").is_some(), "{line}");
            }
            "round" => {
                round_events += 1;
                assert!(j.get("local_delay_p50_s").is_some(), "{line}");
                assert!(j.get("compute_wall_s").is_some(), "{line}");
            }
            "run_start" => {
                run_start += 1;
                assert_eq!(
                    j.get("engine").unwrap().as_str().unwrap(),
                    "fleet"
                );
            }
            "run_end" => run_end += 1,
            _ => {}
        }
    }
    // one span event per phase per round, one round event per round
    assert_eq!(phase_events, rounds * PHASES.len());
    assert_eq!(round_events, rounds);
    assert_eq!(run_start, 1);
    assert_eq!(run_end, 1);
}

#[test]
fn byzantine_run_streams_guard_rejection_events() {
    let mut s = system(40);
    let mut t = MockTrainer::new(40, 600);
    let mut cfg = fleet_cfg(4, 2, 1);
    cfg.max_staleness = 0;
    cfg.weather = "byzantine:1.0".parse().unwrap();
    let mut obs = Observer::with_sink(TraceSink::in_memory());
    let h = fleet::run_traced(&mut s, &mut t, &cfg, "byz", &mut obs).unwrap();
    let rejected: usize = h.rounds.iter().map(|r| r.rejected_updates).sum();
    assert!(rejected > 0, "byzantine:1.0 must reject something");

    let text = obs.sink_buffer().unwrap();
    let mut weather_events = 0usize;
    let mut guard_rejected = 0usize;
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        match j.get("t").unwrap().as_str().unwrap() {
            "weather" => {
                weather_events += 1;
                assert_eq!(
                    j.get("kind").unwrap().as_str().unwrap(),
                    "byzantine"
                );
            }
            "guard_reject" => {
                guard_rejected +=
                    j.get("rejected").unwrap().as_usize().unwrap();
            }
            _ => {}
        }
    }
    assert!(weather_events > 0, "perturbed rounds must stream weather");
    // shard-level rejections stream as they happen; the history's column
    // counts them on commit, so the stream can only see more or equal
    assert!(
        guard_rejected >= rejected,
        "streamed {guard_rejected} < recorded {rejected}"
    );
    assert_eq!(obs.registry.counter("guard_rejections") as usize, guard_rejected);
}

// ---------------------------------------------------------------------------
// bounded bus: evictions route through the stream
// ---------------------------------------------------------------------------

#[test]
fn bus_evictions_route_through_the_trace_stream() {
    let mut s = system(40);
    // a tiny audit ring: a 4-shard round publishes far more than 2
    // messages, so the engine must stage evictions for the sink
    s.bus = AnnouncementBus::new(2);
    let mut t = MockTrainer::new(40, 600);
    let cfg = fleet_cfg(3, 4, 1);
    let mut obs = Observer::with_sink(TraceSink::in_memory());
    fleet::run_traced(&mut s, &mut t, &cfg, "evict", &mut obs).unwrap();
    let text = obs.sink_buffer().unwrap();
    let evicts = text
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .filter(|j| j.get("t").unwrap().as_str().unwrap() == "bus_evict")
        .count();
    assert!(evicts > 0, "capacity-2 bus must evict into the stream");
    assert_eq!(obs.registry.counter("bus_evictions") as usize, evicts);
    // the ring itself stays bounded
    assert!(s.bus.audit().count() <= 2);
    // without a sink the engine leaves eviction staging off: nothing
    // accumulates in the staging buffer on the default path
    let mut s2 = system(40);
    s2.bus = AnnouncementBus::new(2);
    let mut t2 = MockTrainer::new(40, 600);
    fleet::run(&mut s2, &mut t2, &cfg, "plain").unwrap();
    assert!(s2.bus.take_evicted().is_empty());
}

// ---------------------------------------------------------------------------
// compute_wall_s: populated from the train span on the mock path
// ---------------------------------------------------------------------------

#[test]
fn mock_path_populates_compute_wall_s() {
    let mut s = system(20);
    let mut t = MockTrainer::new(20, 600);
    let cfg = TraditionalConfig {
        rounds: 2,
        cohort_size: 6,
        n_rb: 6,
        ..Default::default()
    };
    let h = traditional::run(&mut s, &mut t, &cfg, "wall").unwrap();
    for r in &h.rounds {
        assert!(
            r.compute_wall_s > 0.0,
            "round {}: compute_wall_s = {}",
            r.round,
            r.compute_wall_s
        );
    }

    let mut s = system(40);
    let mut t = MockTrainer::new(40, 600);
    let h = fleet::run(&mut s, &mut t, &fleet_cfg(2, 2, 1), "wall").unwrap();
    assert!(
        h.rounds.iter().any(|r| r.compute_wall_s > 0.0),
        "no fleet round recorded train wall-clock"
    );
}

//! Lexer torture fixture: every determinism-hostile token below lives
//! inside a comment, string, raw string, or char literal. The masked
//! view must blank them all, so this file lints clean even though the
//! analyzer tests scan it under an engine path (`src/fleet/…`).
//! (Never compiled — the walker skips `fixtures/` directories.)

/* block comment mentioning Instant::now and SystemTime
   /* nested: thread_rng() and .unwrap() still masked */
   back at depth one: rand::random */

pub fn tricky() -> usize {
    let url = "https://example.com // not a comment: Instant::now";
    let re = r#"raw "quoted" \ backslash: .unwrap() and thread_rng"#;
    let shout = r##"wider fence r#"inner"# mentioning SystemTime"##;
    let bytes = b"byte string with .expect( inside";
    let colon = ':'; // char literal, not a lifetime
    let newline = '\n';
    let quote = '\'';
    fn lifetime_user<'a>(x: &'a str) -> &'a str {
        x
    }
    let _ = lifetime_user(url);
    url.len()
        + re.len()
        + shout.len()
        + bytes.len()
        + (colon as usize)
        + (newline as usize)
        + (quote as usize)
}

//! Regression fixture for no-ambient-rng split-label collisions.
//!
//! The lint's first sweep of the real tree found exactly one
//! collision: `cnc/optimize.rs::decide_traditional` called
//! `round_rng.split("cohort")` in both the PowerGrouping and the
//! Uniform match arms — two call sites handed the same stream. The fix
//! hoisted a single split above the match (`split` is a pure label
//! hash, so the hoist is bitwise-identical). This file preserves the
//! pre-fix shape so the rule keeps firing on it; the analyzer test
//! scans it under a `src/` path and asserts exactly one finding.
//! (Never compiled — the walker skips `fixtures/` directories.)

pub fn decide(grouped: bool, round_rng: &Pcg64) -> Vec<usize> {
    match grouped {
        true => grouped_sample(&mut round_rng.split("cohort")),
        false => uniform_sample(&mut round_rng.split("cohort")),
    }
}

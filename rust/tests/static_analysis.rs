//! The cnclint gate (tier-1) plus coverage for the analyzer itself.
//!
//! `tree_is_clean` is the gate ISSUE 8 ships: it walks the real source
//! tree and asserts zero unsuppressed findings, so every determinism
//! invariant the rules encode is machine-checked on each `cargo test`.
//! The remaining tests feed the rule engine in-memory fixtures (plus
//! the two on-disk torture fixtures under `tests/fixtures/`, which the
//! tree walker deliberately skips) — one positive and one suppressed
//! case per rule, and the lexing corner cases that could silently
//! blind a rule if the masker regressed.

use std::path::Path;

use cnc_fl::analysis::{analyze_files, analyze_tree, FileData};

/// Lint one in-memory file (no README) and return its finding rules.
fn rules_of(path: &str, src: &str) -> Vec<String> {
    analyze_files(&[FileData::new(path, src)], None)
        .findings
        .iter()
        .map(|f| f.rule.to_string())
        .collect()
}

fn assert_clean(path: &str, src: &str) {
    let found = rules_of(path, src);
    assert!(found.is_empty(), "expected clean, got {found:?}");
}

// -------------------------------------------------------------------
// the gate
// -------------------------------------------------------------------

#[test]
fn tree_is_clean() {
    let report = analyze_tree(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap();
    let listing: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.findings.is_empty(),
        "cnclint found {} unsuppressed finding(s):\n{}",
        report.findings.len(),
        listing.join("\n")
    );
    assert_eq!(report.rules_run, 6);
    assert!(report.files_scanned > 40, "walker lost most of the tree");
}

// -------------------------------------------------------------------
// lexer corner cases (on-disk fixtures, scanned under engine paths)
// -------------------------------------------------------------------

#[test]
fn lexing_torture_fixture_is_invisible_to_every_rule() {
    // nested block comments, raw strings with fences, `//` inside
    // strings, lifetimes vs char literals — all masked, zero findings
    // even under the strictest (engine) path scope.
    let src = include_str!("fixtures/lexing_tricky.rs");
    assert_clean("src/fleet/lexing_tricky.rs", src);
}

#[test]
fn split_label_collision_fixture_still_fires() {
    // regression: the pre-fix shape of cnc/optimize.rs's double
    // split("cohort") must keep producing exactly one finding.
    let src = include_str!("fixtures/split_label_collision.rs");
    let found = rules_of("src/cnc/optimize_regression.rs", src);
    assert_eq!(found, vec!["no-ambient-rng"], "{found:?}");
}

// -------------------------------------------------------------------
// no-unordered-iter
// -------------------------------------------------------------------

#[test]
fn unordered_iter_positive_and_suppressed() {
    let bad = r"
use std::collections::HashMap;
pub fn order(m: &HashMap<u64, usize>) -> Vec<u64> {
    m.keys().copied().collect()
}
";
    assert_eq!(rules_of("src/fleet/x.rs", bad), vec!["no-unordered-iter"]);
    // same file outside the engine dirs: out of scope
    assert_clean("src/exp/x.rs", bad);

    let ok = r"
use std::collections::HashMap;
pub fn count(m: &HashMap<u64, usize>) -> usize {
    // cnclint: allow(no-unordered-iter): counting, order-independent
    m.keys().count()
}
";
    assert_clean("src/fleet/x.rs", ok);
}

#[test]
fn unordered_iter_catches_for_loops_over_bound_names() {
    let bad = r"
use std::collections::HashSet;
pub fn walk(seen: &HashSet<u64>) {
    for id in seen {
        drop(id);
    }
}
";
    assert_eq!(rules_of("src/coordinator/x.rs", bad), vec!["no-unordered-iter"]);
}

// -------------------------------------------------------------------
// no-wall-clock
// -------------------------------------------------------------------

#[test]
fn wall_clock_positive_and_suppressed() {
    let bad = "pub fn t() -> std::time::Instant { std::time::Instant::now() }\n";
    assert_eq!(rules_of("src/netsim/x.rs", bad), vec!["no-wall-clock"]);
    // the clock-owning files are exempt
    assert_clean("src/obs/trace.rs", bad);
    // tests/ and benches/ are out of scope entirely
    assert_clean("tests/x.rs", bad);

    let ok = "// cnclint: allow(no-wall-clock): diagnostics only, never folded into round state\n\
              pub fn t() -> std::time::Instant { std::time::Instant::now() }\n";
    assert_clean("src/netsim/x.rs", ok);
}

// -------------------------------------------------------------------
// no-ambient-rng
// -------------------------------------------------------------------

#[test]
fn ambient_rng_positive_and_suppressed() {
    let bad = "pub fn roll() -> f64 { rand::random() }\n";
    assert_eq!(rules_of("src/cnc/x.rs", bad), vec!["no-ambient-rng"]);

    let ok = "// cnclint: allow(no-ambient-rng): fixture exercising the ban itself\n\
              pub fn roll() -> f64 { rand::random() }\n";
    assert_clean("src/cnc/x.rs", ok);

    // distinct labels in one module are fine
    let distinct = r#"
pub fn two(rng: &Pcg64) -> (Pcg64, Pcg64) {
    (rng.split("alpha"), rng.split("beta"))
}
"#;
    assert_clean("src/cnc/x.rs", distinct);

    // duplicate labels under #[cfg(test)] are tolerated (tests pin
    // determinism on purpose-made streams)
    let test_side = "#[cfg(test)]\nmod tests {\n    fn f(r: &Pcg64) {\n        \
                     r.split(\"dup\");\n        r.split(\"dup\");\n    }\n}\n";
    assert_clean("src/cnc/x.rs", test_side);
}

// -------------------------------------------------------------------
// no-unwrap-in-lib
// -------------------------------------------------------------------

#[test]
fn unwrap_in_lib_positive_and_suppressed() {
    let bad = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    assert_eq!(rules_of("src/coordinator/x.rs", bad), vec!["no-unwrap-in-lib"]);
    assert_eq!(rules_of("src/model/x.rs", bad), vec!["no-unwrap-in-lib"]);
    // non-engine modules may unwrap (exp/, util/, …)
    assert_clean("src/util/x.rs", bad);

    // expect() is equally banned
    let expect = "pub fn f(x: Option<u32>) -> u32 { x.expect(\"set\") }\n";
    assert_eq!(rules_of("src/transport/x.rs", expect), vec!["no-unwrap-in-lib"]);

    // test modules are exempt
    let in_tests =
        "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 { x.unwrap() }\n}\n";
    assert_clean("src/coordinator/x.rs", in_tests);

    let ok = "pub fn f(x: Option<u32>) -> u32 {\n    \
              // cnclint: allow(no-unwrap-in-lib): caller guarantees Some by construction\n    \
              x.unwrap()\n}\n";
    assert_clean("src/coordinator/x.rs", ok);
}

// -------------------------------------------------------------------
// config-literal-exhaustive
// -------------------------------------------------------------------

#[test]
fn config_literal_positive_suppressed_and_defining_module() {
    let bad = "fn make() -> FleetConfig {\n    FleetConfig { rounds: 3, seed: 1 }\n}\n";
    assert_eq!(rules_of("tests/x.rs", bad), vec!["config-literal-exhaustive"]);

    let ok = "fn make() -> FleetConfig {\n    \
              FleetConfig { rounds: 3, ..Default::default() }\n}\n";
    assert_clean("tests/x.rs", ok);

    // nested `..` at depth 2 does not satisfy the outer literal
    let nested = "fn make() -> FleetConfig {\n    FleetConfig { transport: \
                  TransportConfig { ..Default::default() }, rounds: 3 }\n}\n";
    assert_eq!(rules_of("tests/x.rs", nested), vec!["config-literal-exhaustive"]);

    // the defining module's exhaustive Default impl is exempt
    let defining = "pub struct FleetConfig {\n    pub rounds: usize,\n}\n\
                    impl Default for FleetConfig {\n    fn default() -> FleetConfig {\n        \
                    FleetConfig { rounds: 50 }\n    }\n}\n";
    assert_clean("src/fleet/async_round.rs", defining);

    let suppressed = "fn make() -> FleetConfig {\n    \
                      // cnclint: allow(config-literal-exhaustive): asserts every field on purpose\n    \
                      FleetConfig { rounds: 3, seed: 1 }\n}\n";
    assert_clean("tests/x.rs", suppressed);
}

// -------------------------------------------------------------------
// csv-schema-sync
// -------------------------------------------------------------------

const CSV_FIXTURE_OK: &str = r#"
pub struct RoundRecord {
    pub round: usize,
    pub accuracy: f64,
}
impl RunHistory {
    pub fn to_csv(&self) -> CsvTable {
        CsvTable::new(&[
            "round",
            "accuracy",
        ])
    }
}
"#;

#[test]
fn csv_schema_sync_positive_and_suppressed() {
    assert_clean("src/metrics/mod.rs", CSV_FIXTURE_OK);

    // a field the header never emits
    let drifted = CSV_FIXTURE_OK.replace(
        "pub accuracy: f64,",
        "pub accuracy: f64,\n    pub extra_things: usize,",
    );
    assert_eq!(rules_of("src/metrics/mod.rs", &drifted), vec!["csv-schema-sync"]);

    let excused = CSV_FIXTURE_OK.replace(
        "pub accuracy: f64,",
        "pub accuracy: f64,\n    \
         // cnclint: allow(csv-schema-sync): reported via the trace stream\n    \
         pub extra_things: usize,",
    );
    assert_clean("src/metrics/mod.rs", &excused);

    // a column no field backs
    let phantom = CSV_FIXTURE_OK.replace("\"accuracy\",", "\"accuracy\",\n            \"phantom\",");
    assert_eq!(rules_of("src/metrics/mod.rs", &phantom), vec!["csv-schema-sync"]);
}

#[test]
fn csv_schema_sync_checks_the_readme_table() {
    let files = [FileData::new("src/metrics/mod.rs", CSV_FIXTURE_OK)];
    let good = "## CSV schema\n\n| column | meaning |\n|---|---|\n\
                | `round` | global round index |\n| `accuracy` | test accuracy |\n";
    assert!(analyze_files(&files, Some(good)).findings.is_empty());

    let wrong_order = "## CSV schema\n\n| column | meaning |\n|---|---|\n\
                       | `accuracy` | test accuracy |\n| `round` | global round index |\n";
    let r = analyze_files(&files, Some(wrong_order));
    assert_eq!(r.findings.len(), 1);
    assert_eq!(r.findings[0].file, "README.md");
    assert_eq!(r.findings[0].rule, "csv-schema-sync");

    let missing_section = "# readme\n\nno schema table here\n";
    let r = analyze_files(&files, Some(missing_section));
    assert_eq!(r.findings.len(), 1);
    assert_eq!(r.findings[0].file, "README.md");
}

// -------------------------------------------------------------------
// suppression hygiene
// -------------------------------------------------------------------

#[test]
fn suppressions_require_a_reason_and_a_known_rule() {
    let no_reason = "pub fn f(x: Option<u32>) -> u32 {\n    \
                     // cnclint: allow(no-unwrap-in-lib):\n    \
                     x.unwrap()\n}\n";
    let found = rules_of("src/coordinator/x.rs", no_reason);
    assert!(
        found.contains(&"suppression-syntax".to_string()),
        "reasonless allow must be rejected: {found:?}"
    );
    assert!(
        found.contains(&"no-unwrap-in-lib".to_string()),
        "a malformed allow must not suppress the finding: {found:?}"
    );

    let unknown = "// cnclint: allow(no-such-rule): some reason\npub fn f() {}\n";
    assert_eq!(rules_of("src/cnc/x.rs", unknown), vec!["suppression-syntax"]);
}

#[test]
fn suppression_must_sit_on_or_directly_above_the_finding() {
    let too_far = "pub fn f(x: Option<u32>) -> u32 {\n    \
                   // cnclint: allow(no-unwrap-in-lib): stale marker\n    \
                   let y = x;\n    y.unwrap()\n}\n";
    assert_eq!(rules_of("src/coordinator/x.rs", too_far), vec!["no-unwrap-in-lib"]);
}

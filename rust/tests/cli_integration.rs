//! CLI integration: drive the `cnc-fl` binary end to end (mock backend —
//! fast) and check that the figure harness produces well-formed CSVs.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target/<profile>/cnc-fl next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // <profile>/
    p.push("cnc-fl");
    p
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn cnc-fl");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
    )
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("cnc_fl_cli_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn no_args_prints_usage() {
    let (ok, stdout, _) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("subcommands"));
    assert!(stdout.contains("fig11"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn table1_and_table2_print_constants() {
    let (ok, stdout, _) = run(&["table1"]);
    assert!(ok, "{stdout}");
    assert!(stdout.contains("-174 dBm/Hz"));
    assert!(stdout.contains("0.606 MB"));
    let (ok, stdout, _) = run(&["table2"]);
    assert!(ok);
    for case in ["Pr1", "Pr6"] {
        assert!(stdout.contains(case));
    }
}

#[test]
fn run_subcommand_mock_writes_csv() {
    let out = tmpdir("run");
    let (ok, stdout, stderr) = run(&[
        "run",
        "--case",
        "Pr1",
        "--method",
        "cnc",
        "--rounds",
        "5",
        "--backend",
        "mock",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let csv = std::fs::read_to_string(out.join("run_Pr1_cnc_iid.csv")).unwrap();
    assert!(csv.starts_with("round,accuracy"));
    assert_eq!(csv.lines().count(), 6);
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn fig11_mock_writes_csv_with_nan_for_big_tsp() {
    let out = tmpdir("fig11");
    let (ok, stdout, stderr) = run(&[
        "fig11",
        "--rounds",
        "3",
        "--backend",
        "mock",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let csv = std::fs::read_to_string(out.join("fig11.csv")).unwrap();
    assert!(csv.starts_with("num_clients,"));
    // 6 fleet sizes
    assert_eq!(csv.lines().count(), 7);
    // n=24/28 rows carry NaN in the TSP column
    assert!(csv.contains("NaN"));
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn p2p_subcommand_mock() {
    let out = tmpdir("p2p");
    let (ok, stdout, stderr) = run(&[
        "p2p",
        "--clients",
        "12",
        "--parts",
        "3",
        "--rounds",
        "4",
        "--backend",
        "mock",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("final accuracy"));
    assert!(out.join("p2p_12c_3e.csv").exists());
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn fleet10k_subcommand_completes_five_sharded_rounds() {
    // acceptance: the Fleet10k preset (10⁴ clients) completes a 5-round
    // mock run with sharded decisions and writes the shard/staleness CSV
    let out = tmpdir("fleet");
    let (ok, stdout, stderr) = run(&[
        "fleet",
        "--case",
        "Fleet10k",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("10000 clients / 16 shards"), "{stdout}");
    assert!(stdout.contains("model mlp-784 (101770 params"), "{stdout}");
    assert!(stdout.contains("final accuracy"));
    let csv =
        std::fs::read_to_string(out.join("fleet_Fleet10k_mlp-784_16s_2k.csv"))
            .unwrap();
    assert!(csv.starts_with("round,accuracy"));
    let header = csv.lines().next().unwrap();
    assert!(header.contains("shards_committed"));
    assert!(header.contains("staleness_mean"));
    assert_eq!(csv.lines().count(), 6); // header + 5 rounds
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn fleet_model_override_swaps_the_arena_without_recompiling() {
    // the dynamic-shape axis end-to-end: the same binary sweeps three
    // model sizes through full sharded rounds via `--model`
    let out = tmpdir("fleet-shapes");
    for (model, params) in
        [("mlp-small", "25450"), ("mlp-784", "101770"), ("mlp-wide", "998530")]
    {
        let (ok, stdout, stderr) = run(&[
            "fleet",
            "--case",
            "Fleet10k",
            "--rounds",
            "2",
            "--model",
            model,
            "--out",
            out.to_str().unwrap(),
        ]);
        assert!(ok, "model={model} stdout={stdout} stderr={stderr}");
        assert!(
            stdout.contains(&format!("model {model} ({params} params")),
            "{model}: {stdout}"
        );
        assert!(
            out.join(format!("fleet_Fleet10k_{model}_16s_2k.csv")).exists(),
            "{model}"
        );
    }
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn fleet_region_tier_and_churn_flags_work() {
    // the CI smoke line plus churn: `--preset` aliases `--case`, the
    // region tier and churn knobs validate and run, and the CSV carries
    // the new region/rebalance columns in a region-tagged file
    let out = tmpdir("fleet-regions");
    let (ok, stdout, stderr) = run(&[
        "fleet",
        "--preset",
        "Fleet10k",
        "--rounds",
        "2",
        "--regions",
        "2",
        "--churn",
        "1:0.1",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("10000 clients / 16 shards / 2 regions"), "{stdout}");
    let csv = std::fs::read_to_string(
        out.join("fleet_Fleet10k_mlp-784_16s_2k_r2.csv"),
    )
    .unwrap();
    let header = csv.lines().next().unwrap();
    assert!(header.contains("regions_committed"), "{header}");
    assert!(header.contains("rebalance_moves"), "{header}");
    assert_eq!(csv.lines().count(), 3); // header + 2 rounds
    // a bad region count is rejected up front by FleetConfig::validate
    let (ok, _, stderr) = run(&[
        "fleet", "--preset", "Fleet10k", "--rounds", "1", "--regions", "99",
    ]);
    assert!(!ok);
    assert!(stderr.contains("regions"), "{stderr}");
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn fleet_codec_flag_compresses_the_uplink_and_tags_the_csv() {
    // the transport plane end-to-end: `--codec quant8` on the Fleet10k
    // preset must land the new byte columns in a codec-tagged CSV with
    // ≥ 3.5× fewer uplink bytes per round than raw (acceptance bar)
    let out = tmpdir("fleet-codec");
    for codec in ["raw", "quant8"] {
        let (ok, stdout, stderr) = run(&[
            "fleet",
            "--preset",
            "Fleet10k",
            "--rounds",
            "2",
            "--codec",
            codec,
            "--out",
            out.to_str().unwrap(),
        ]);
        assert!(ok, "codec={codec} stdout={stdout} stderr={stderr}");
        assert!(stdout.contains(&format!("codec {codec}")), "{stdout}");
    }
    let read = |name: &str| {
        std::fs::read_to_string(out.join(name)).unwrap()
    };
    let raw_csv = read("fleet_Fleet10k_mlp-784_16s_2k.csv");
    let q8_csv = read("fleet_Fleet10k_mlp-784_16s_2k_quant8.csv");
    let header = raw_csv.lines().next().unwrap();
    for col in ["uplink_bytes", "backhaul_bytes", "broadcast_bytes", "comm_delay_s"] {
        assert!(header.contains(col), "{header}");
    }
    let col = header.split(',').position(|c| c == "uplink_bytes").unwrap();
    let uplink = |csv: &str| -> Vec<f64> {
        csv.lines()
            .skip(1)
            .map(|l| l.split(',').nth(col).unwrap().parse().unwrap())
            .collect()
    };
    let raw_bytes = uplink(&raw_csv);
    let q8_bytes = uplink(&q8_csv);
    for (r, q) in raw_bytes.iter().zip(&q8_bytes) {
        if *r == 0.0 {
            continue; // async round with no commits
        }
        assert!(
            r / q >= 3.5,
            "quant8 uplink bytes only {:.2}x smaller",
            r / q
        );
    }
    // a malformed codec is rejected up front
    let (ok, _, stderr) = run(&[
        "fleet", "--preset", "Fleet10k", "--rounds", "1", "--codec", "gzip",
    ]);
    assert!(!ok);
    assert!(stderr.contains("codec"), "{stderr}");
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn fleet_weather_flag_counts_rejections_and_tags_the_csv() {
    // the CI byzantine smoke as a test: poisoned updates are rejected
    // (nonzero rejected_updates column) in a weather-tagged CSV, and a
    // malformed spec is refused up front by the parser
    let out = tmpdir("fleet-weather");
    let (ok, stdout, stderr) = run(&[
        "fleet",
        "--preset",
        "Fleet10k",
        "--rounds",
        "3",
        "--regions",
        "2",
        "--weather",
        "byzantine:0.2",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("weather byz0.2"), "{stdout}");
    let csv = std::fs::read_to_string(
        out.join("fleet_Fleet10k_mlp-784_16s_2k_r2_byz0.2.csv"),
    )
    .unwrap();
    let header = csv.lines().next().unwrap();
    let col = header
        .split(',')
        .position(|c| c == "rejected_updates")
        .expect("rejected_updates column");
    let rejected: f64 = csv
        .lines()
        .skip(1)
        .map(|l| l.split(',').nth(col).unwrap().parse::<f64>().unwrap())
        .sum();
    assert!(rejected > 0.0, "byzantine weather rejected nothing:\n{csv}");
    // malformed weather and guard specs are rejected before the run
    let (ok, _, stderr) = run(&[
        "fleet", "--preset", "Fleet10k", "--rounds", "1", "--weather", "gale",
    ]);
    assert!(!ok);
    assert!(stderr.contains("weather"), "{stderr}");
    let (ok, _, stderr) = run(&[
        "fleet", "--preset", "Fleet10k", "--rounds", "1", "--guard", "on:0",
    ]);
    assert!(!ok);
    assert!(stderr.contains("guard"), "{stderr}");
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn fleet_trace_flag_streams_jsonl_and_prints_the_rollup() {
    // the observability plane end-to-end: bare `--trace` writes the
    // default-tagged JSONL next to the CSV, every line is an object
    // with a "t" tag, phase spans cover each round, and the summary
    // reports the delay rollup plus the trace destination
    let out = tmpdir("fleet-trace");
    let (ok, stdout, stderr) = run(&[
        "fleet",
        "--preset",
        "Fleet10k",
        "--rounds",
        "2",
        "--trace",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(stdout.contains("delay rollup: local p50/p95/p99"), "{stdout}");
    assert!(stdout.contains("trace →"), "{stdout}");
    let trace = std::fs::read_to_string(
        out.join("trace_fleet_Fleet10k_mlp-784_16s_2k.jsonl"),
    )
    .unwrap();
    let mut phases = 0usize;
    for line in trace.lines() {
        assert!(
            line.starts_with("{\"t\":\"") && line.ends_with('}'),
            "not an event object: {line}"
        );
        if line.starts_with("{\"t\":\"phase\"") {
            phases += 1;
        }
    }
    assert!(phases > 0, "no phase events:\n{trace}");
    // explicit path form: --trace=PATH lands the stream there instead
    let explicit = out.join("custom.jsonl");
    let arg = format!("--trace={}", explicit.display());
    let (ok, stdout, stderr) = run(&[
        "fleet",
        "--preset",
        "Fleet10k",
        "--rounds",
        "1",
        &arg,
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    assert!(explicit.exists());
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn run_codec_flag_works_on_the_traditional_engine() {
    let out = tmpdir("run-codec");
    let (ok, stdout, stderr) = run(&[
        "run",
        "--case",
        "Pr1",
        "--rounds",
        "2",
        "--backend",
        "mock",
        "--codec",
        "topk:0.2",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "stdout={stdout} stderr={stderr}");
    let csv =
        std::fs::read_to_string(out.join("run_Pr1_cnc_iid_topk0.2.csv")).unwrap();
    assert!(csv.lines().next().unwrap().contains("uplink_bytes"));
    let _ = std::fs::remove_dir_all(out);
}

#[test]
fn shapes_subcommand_lists_presets() {
    let (ok, stdout, stderr) = run(&["shapes"]);
    assert!(ok, "stderr={stderr}");
    for name in ["mlp-small", "mlp-784", "mlp-wide"] {
        assert!(stdout.contains(name), "{stdout}");
    }
    assert!(stdout.contains("101770"), "{stdout}");
}

#[test]
fn bad_flag_value_reports_error() {
    let (ok, _, stderr) = run(&["run", "--method", "nonsense", "--backend", "mock"]);
    assert!(!ok);
    assert!(stderr.contains("unknown method"));
}

//! Transport-plane properties: the raw codec is bit-invisible (every
//! engine produces the exact pre-transport histories), a lossless
//! non-raw wire (top-k at keep = 1.0) changes *only* the byte
//! accounting, lossy codecs reach both the bytes and the model, and the
//! per-tier CSV byte columns are exactly `codec wire size × transfer
//! count` (mock backend — no artifacts needed).

use cnc_fl::cnc::optimize::CohortStrategy;
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::MockTrainer;
use cnc_fl::fleet::{self, FleetConfig};
use cnc_fl::metrics::RunHistory;
use cnc_fl::model::shape::{ModelShape, PRESET_NAMES};
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::transport::{PayloadCodec, TransportConfig, TransportPlan};

fn system(n: usize, seed: u64) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 2;
    CncSystem::bootstrap(n, 600, 1, PowerProfile::Bimodal, ch, seed)
}

fn fleet_cfg(codec: PayloadCodec, threads: usize) -> FleetConfig {
    FleetConfig {
        rounds: 4,
        shards: 3,
        regions: 2,
        max_staleness: 1,
        cohort_size: 6,
        n_rb: 6,
        cohort_strategy: CohortStrategy::PowerGrouping { m: 4 },
        threads,
        transport: TransportConfig {
            codec,
            ..Default::default()
        },
        seed: 11,
        ..Default::default()
    }
}

fn assert_training_bitwise_equal(a: &RunHistory, b: &RunHistory, tag: &str) {
    assert_eq!(a.rounds.len(), b.rounds.len(), "{tag}");
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(
            x.accuracy.to_bits(),
            y.accuracy.to_bits(),
            "{tag}: round {} accuracy {} vs {}",
            x.round,
            x.accuracy,
            y.accuracy
        );
        assert_eq!(
            x.train_loss.to_bits(),
            y.train_loss.to_bits(),
            "{tag}: round {} loss",
            x.round
        );
        assert_eq!(x.local_delays_s, y.local_delays_s, "{tag}");
        assert_eq!(x.shards_committed, y.shards_committed, "{tag}");
        assert_eq!(x.regions_committed, y.regions_committed, "{tag}");
        assert_eq!(x.dropouts, y.dropouts, "{tag}");
    }
}

// ---------------------------------------------------------------------------
// raw-codec bit-identity: the transport refactor is pure re-plumbing
// ---------------------------------------------------------------------------

#[test]
fn raw_codec_fleet_degenerate_matches_traditional_for_every_preset_and_width() {
    // the satellite contract: with `--codec raw` (stated explicitly, not
    // just defaulted) the refactored engines reproduce the pre-transport
    // behaviour — pinned through the flat ≡ degenerate-fleet equality
    // across all three shape presets × {serial, parallel}
    for name in PRESET_NAMES {
        let shape = ModelShape::preset(name).unwrap();
        for threads in [1usize, 4] {
            let raw_transport = TransportConfig {
                codec: PayloadCodec::Raw,
                ..Default::default()
            };
            let trad = {
                let mut sys = system(30, 7);
                let mut t = MockTrainer::with_shape(30, 600, &shape);
                let cfg = TraditionalConfig {
                    rounds: 3,
                    cohort_size: 6,
                    n_rb: 6,
                    cohort_strategy: CohortStrategy::PowerGrouping { m: 5 },
                    threads,
                    transport: raw_transport.clone(),
                    seed: 7,
                    ..Default::default()
                };
                traditional::run(&mut sys, &mut t, &cfg, "flat").unwrap()
            };
            let flt = {
                let mut sys = system(30, 7);
                let mut t = MockTrainer::with_shape(30, 600, &shape);
                let cfg = FleetConfig {
                    rounds: 3,
                    shards: 1,
                    regions: 1,
                    max_staleness: 0,
                    cohort_size: 6,
                    n_rb: 6,
                    cohort_strategy: CohortStrategy::PowerGrouping { m: 5 },
                    threads,
                    transport: raw_transport,
                    seed: 7,
                    ..Default::default()
                };
                fleet::run(&mut sys, &mut t, &cfg, "fleet").unwrap()
            };
            assert_training_bitwise_equal(
                &trad,
                &flt,
                &format!("{name}/threads{threads}"),
            );
            // and both charge the identical raw byte columns
            for (x, y) in trad.rounds.iter().zip(&flt.rounds) {
                assert_eq!(x.uplink_bytes, y.uplink_bytes, "{name}");
                assert_eq!(x.uplink_bytes, 6 * shape.payload_bytes(), "{name}");
            }
        }
    }
}

#[test]
fn lossless_wire_changes_bytes_but_not_one_bit_of_training() {
    // top-k at keep = 1.0 round-trips exactly, but its wire format costs
    // 8 B/entry instead of 4 — so a run with it must produce bitwise the
    // same models/accuracies as raw while charging ~2× the uplink bytes
    // (and, through Eq (3), ~2× the uplink delay). This pins that the
    // codec plumbing touches *only* the wire.
    for threads in [1usize, 4] {
        let run_with = |codec: PayloadCodec| {
            let mut sys = system(36, 3);
            let mut t = MockTrainer::new(36, 600);
            let cfg = fleet_cfg(codec, threads);
            fleet::run(&mut sys, &mut t, &cfg, "wire").unwrap()
        };
        let raw = run_with(PayloadCodec::Raw);
        let lossless = run_with(PayloadCodec::TopK { keep_frac: 1.0 });
        assert_training_bitwise_equal(&raw, &lossless, "lossless-wire");
        let ub_raw: usize = raw.rounds.iter().map(|r| r.uplink_bytes).sum();
        let ub_lossless: usize =
            lossless.rounds.iter().map(|r| r.uplink_bytes).sum();
        assert!(
            ub_lossless as f64 > 1.9 * ub_raw as f64,
            "index+value pairs must cost ~2× raw: {ub_lossless} vs {ub_raw}"
        );
        // broadcast stays dense either way
        for (x, y) in raw.rounds.iter().zip(&lossless.rounds) {
            assert_eq!(x.broadcast_bytes, y.broadcast_bytes);
        }
    }
}

// ---------------------------------------------------------------------------
// byte accounting: CSV columns == codec wire size × transfer count
// ---------------------------------------------------------------------------

#[test]
fn byte_columns_are_codec_wire_size_times_transfer_count() {
    let shape = ModelShape::paper();
    let codec = PayloadCodec::Quant8;
    let transport = TransportConfig {
        codec,
        ..Default::default()
    };
    let plan = TransportPlan::new(&shape, &transport).unwrap();
    let ub = plan.update_bytes();
    let raw = plan.broadcast_model_bytes();
    assert_eq!(ub, codec.payload_bytes_for(&shape));

    let mut sys = system(40, 5);
    let mut t = MockTrainer::new(40, 600);
    let cfg = FleetConfig {
        rounds: 3,
        shards: 4,
        regions: 2,
        max_staleness: 0, // synchronous: every shard decides and commits
        cohort_size: 8,
        n_rb: 8,
        cohort_strategy: CohortStrategy::PowerGrouping { m: 5 },
        transport,
        seed: 5,
        ..Default::default()
    };
    let h = fleet::run(&mut sys, &mut t, &cfg, "bytes").unwrap();
    let mut csv_total = 0usize;
    let mut expect_total = 0usize;
    for r in &h.rounds {
        // per tier: cohort uplinks, 4-shard broadcast, 4 shard partials
        // up the shard backhaul + 2 region partials up the region one
        assert_eq!(r.uplink_bytes, 8 * ub, "round {}", r.round);
        assert_eq!(r.broadcast_bytes, 4 * raw);
        assert_eq!(r.backhaul_bytes, (4 + 2) * ub);
        assert!(r.comm_delay_s > 0.0);
        assert!(r.comm_delay_s >= r.tx_delay_round_s());
        csv_total += r.uplink_bytes + r.backhaul_bytes + r.broadcast_bytes;
        expect_total += 8 * ub + 4 * raw + 6 * ub;
    }
    assert_eq!(csv_total, expect_total);
}

// ---------------------------------------------------------------------------
// lossy codecs reach bytes, Eq (3) delays AND the model
// ---------------------------------------------------------------------------

#[test]
fn quant8_cuts_uplink_bytes_and_delays_at_least_3_5x_and_is_lossy() {
    let run_with = |codec: PayloadCodec| {
        let mut sys = system(36, 9);
        let mut t = MockTrainer::new(36, 600);
        let cfg = fleet_cfg(codec, 1);
        fleet::run_with_model(&mut sys, &mut t, &cfg, "q8").unwrap()
    };
    let (h_raw, g_raw) = run_with(PayloadCodec::Raw);
    let (h_q8, g_q8) = run_with(PayloadCodec::Quant8);
    for (a, b) in h_raw.rounds.iter().zip(&h_q8.rounds) {
        if a.uplink_bytes == 0 {
            continue; // an async round with no commits charges nothing
        }
        let byte_ratio = a.uplink_bytes as f64 / b.uplink_bytes as f64;
        assert!(
            byte_ratio >= 3.5,
            "round {}: quant8 only {byte_ratio:.2}× fewer uplink bytes",
            a.round
        );
        // Eq (3) charges the compressed Z(w): the same cohort's slowest
        // uplink shrinks by (nearly) the same factor
        let delay_ratio = a.tx_delay_round_s() / b.tx_delay_round_s();
        assert!(
            delay_ratio > 3.0,
            "round {}: compressed Z(w) not charged (ratio {delay_ratio:.2})",
            a.round
        );
    }
    // lossiness reaches the model — quantization error survives the fold
    assert!(
        g_raw.max_abs_diff(&g_q8) > 0.0,
        "quant8 wire must perturb the global model"
    );
}

#[test]
fn charged_channel_is_restored_even_when_the_run_errors() {
    // mid-run failures must not leak the codec-scaled Z(w) back to the
    // caller's CncSystem (a retry would otherwise compound the scaling)
    let mut sys = system(20, 21);
    let before = sys.pool.channel.payload_bytes;
    let mut t = MockTrainer::new(20, 600);
    let cfg = TraditionalConfig {
        rounds: 2,
        cohort_size: 4,
        n_rb: 4,
        tx_deadline_s: Some(1e-12), // nobody can make this: round 0 bails
        transport: TransportConfig {
            codec: PayloadCodec::Quant8,
            ..Default::default()
        },
        seed: 21,
        ..Default::default()
    };
    assert!(traditional::run(&mut sys, &mut t, &cfg, "err").is_err());
    assert_eq!(sys.pool.channel.payload_bytes.to_bits(), before.to_bits());
}

#[test]
fn topk_fraction_scales_the_wire_and_the_run_completes() {
    let mut sys = system(36, 13);
    let mut t = MockTrainer::new(36, 600);
    let cfg = fleet_cfg(PayloadCodec::TopK { keep_frac: 0.25 }, 1);
    let h = fleet::run(&mut sys, &mut t, &cfg, "topk").unwrap();
    let raw_bytes = ModelShape::paper().payload_bytes();
    let committed: Vec<_> =
        h.rounds.iter().filter(|r| r.uplink_bytes > 0).collect();
    assert!(!committed.is_empty());
    for r in &committed {
        // kept quarter at 8 B/entry ≈ half the raw bytes, per uplink
        let per_update = r.uplink_bytes as f64
            / (r.tx_delays_s.len().max(1)) as f64;
        let frac = per_update / raw_bytes as f64;
        assert!((0.45..0.55).contains(&frac), "round {}: {frac}", r.round);
    }
    // the engine restored the channel's Z(w) it charged for the run
    assert_eq!(sys.pool.channel.payload_bytes, 0.606e6);
}

//! Property-based integration tests over the coordinator invariants
//! (mock backend — no artifacts needed). These are the L3 invariants
//! DESIGN.md calls out: cohort validity, routing validity, aggregation
//! conservation, metric bookkeeping and strategy dominance.

use cnc_fl::cnc::optimize::{CohortStrategy, PartitionStrategy, RbStrategy};
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::p2p::{self, P2pConfig};
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::MockTrainer;
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::netsim::topology::TopologyGen;
use cnc_fl::util::propcheck::{check, gen_usize, prop_assert, GenPair};
use cnc_fl::util::rng::Pcg64;

fn system(n: usize, seed: u64) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 2; // cheap MC for property sweeps
    CncSystem::bootstrap(n, 600, 1, PowerProfile::Bimodal, ch, seed)
}

#[test]
fn traditional_rounds_always_complete_with_valid_metrics() {
    check(
        25,
        GenPair(gen_usize(10..60), gen_usize(0..10_000)),
        |&(u, seed)| {
            let n = (u / 5).max(1);
            let mut sys = system(u, seed as u64);
            let mut t = MockTrainer::new(u, 600);
            let cfg = TraditionalConfig {
                rounds: 3,
                cohort_size: n,
                n_rb: n,
                cohort_strategy: CohortStrategy::PowerGrouping {
                    m: (u / n).clamp(1, u),
                },
                seed: seed as u64,
                ..Default::default()
            };
            let h = traditional::run(&mut sys, &mut t, &cfg, "prop").unwrap();
            for r in &h.rounds {
                if r.local_delays_s.len() != n
                    || r.tx_delays_s.len() != n
                    || r.tx_energies_j.len() != n
                {
                    return Err("metric vectors must match cohort size".into());
                }
                if !r.tx_delays_s.iter().all(|x| x.is_finite() && *x > 0.0) {
                    return Err("tx delays must be positive finite".into());
                }
                if !(0.0..=1.0).contains(&r.accuracy) {
                    return Err("accuracy out of range".into());
                }
            }
            prop_assert(h.rounds.len() == 3, "all rounds ran")
        },
    );
}

#[test]
fn p2p_every_client_visited_exactly_once_per_round() {
    check(
        20,
        GenPair(gen_usize(4..24), gen_usize(0..10_000)),
        |&(u, seed)| {
            let e = (u / 5).max(1);
            let mut sys = system(u, seed as u64);
            let mut t = MockTrainer::new(u, 600);
            let mut rng = Pcg64::seed_from(seed as u64);
            let g = TopologyGen::full(u, 1.0, 10.0, &mut rng);
            let cfg = P2pConfig {
                rounds: 2,
                partition_strategy: PartitionStrategy::BalancedDelay { e },
                seed: seed as u64,
                ..Default::default()
            };
            p2p::run(&mut sys, &mut t, &g, &cfg, "prop").unwrap();
            prop_assert(
                t.calls() == 2 * u,
                &format!("expected {} training calls, got {}", 2 * u, t.calls()),
            )
        },
    );
}

#[test]
fn cnc_delay_spread_dominates_fedavg_across_seeds() {
    // the paper's core claim must hold for *every* seed, not on average
    check(10, gen_usize(0..10_000), |&seed| {
        let u = 80;
        let run_with = |cs, rb, seed: u64| {
            let mut sys = system(u, seed);
            let mut t = MockTrainer::new(u, 600);
            let cfg = TraditionalConfig {
                rounds: 15,
                cohort_size: 8,
                n_rb: 8,
                cohort_strategy: cs,
                rb_strategy: rb,
                eval_every: 15,
                seed,
                ..Default::default()
            };
            traditional::run(&mut sys, &mut t, &cfg, "x").unwrap()
        };
        let h_cnc = run_with(
            CohortStrategy::PowerGrouping { m: 10 },
            RbStrategy::HungarianEnergy,
            seed as u64,
        );
        let h_avg = run_with(
            CohortStrategy::Uniform,
            RbStrategy::Random,
            seed as u64,
        );
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let c = mean(&h_cnc.delay_diffs());
        let a = mean(&h_avg.delay_diffs());
        prop_assert(c < a, &format!("cnc {c:.3} !< fedavg {a:.3} (seed {seed})"))
    });
}

#[test]
fn p2p_partition_count_bounds_round_chain_delay() {
    // more parallel chains → shorter straggler chain, for every seed
    check(10, gen_usize(0..10_000), |&seed| {
        let u = 20;
        let run_with = |e, seed: u64| {
            let mut sys = system(u, seed);
            let mut t = MockTrainer::new(u, 600);
            let mut rng = Pcg64::seed_from(seed);
            let g = TopologyGen::full(u, 1.0, 10.0, &mut rng);
            let cfg = P2pConfig {
                rounds: 2,
                partition_strategy: PartitionStrategy::BalancedDelay { e },
                eval_every: 2,
                seed,
                ..Default::default()
            };
            p2p::run(&mut sys, &mut t, &g, &cfg, "x").unwrap()
        };
        let h4 = run_with(4, seed as u64);
        let h1 = run_with(1, seed as u64);
        let d4 = h4.rounds[0].local_delay_round_s();
        let d1 = h1.rounds[0].local_delay_round_s();
        prop_assert(d4 < d1, &format!("E=4 {d4:.2} !< E=1 {d1:.2}"))
    });
}

#[test]
fn aggregation_weights_are_conserved() {
    // weighted_average over equal models must return the model regardless
    // of cohort composition — checked through a full coordinator round by
    // giving the mock a zero rate (no training movement)
    check(
        20,
        GenPair(gen_usize(5..40), gen_usize(0..10_000)),
        |&(u, seed)| {
            let mut sys = system(u, seed as u64);
            let mut t = MockTrainer::new(u, 600);
            t.rate = 0.0; // training is identity
            let cfg = TraditionalConfig {
                rounds: 2,
                cohort_size: (u / 3).max(1),
                n_rb: (u / 3).max(1),
                cohort_strategy: CohortStrategy::Uniform,
                rb_strategy: RbStrategy::Random,
                seed: seed as u64,
                ..Default::default()
            };
            let h = traditional::run(&mut sys, &mut t, &cfg, "agg").unwrap();
            // identity training → accuracy constant across rounds
            let a: Vec<f64> = h.accuracies();
            prop_assert(
                (a[0] - a[1]).abs() < 1e-9,
                "identity training must leave the global model fixed",
            )
        },
    );
}

#[test]
fn bus_message_flow_is_exactly_four_per_traditional_round() {
    check(
        15,
        GenPair(gen_usize(10..50), gen_usize(0..10_000)),
        |&(u, seed)| {
            let rounds = 4;
            let mut sys = system(u, seed as u64);
            let mut t = MockTrainer::new(u, 600);
            let cfg = TraditionalConfig {
                rounds,
                cohort_size: (u / 5).max(1),
                n_rb: (u / 5).max(1),
                cohort_strategy: CohortStrategy::Uniform,
                rb_strategy: RbStrategy::Random,
                seed: seed as u64,
                ..Default::default()
            };
            traditional::run(&mut sys, &mut t, &cfg, "bus").unwrap();
            prop_assert(
                sys.bus.published() == rounds * 4,
                &format!("bus carried {} msgs, want {}", sys.bus.published(), rounds * 4),
            )
        },
    );
}

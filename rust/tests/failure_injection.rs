//! Failure-injection tests: the coordinator must surface backend errors
//! cleanly (no partial aggregation, no poisoned state) and the CNC
//! decision layer must reject impossible topologies rather than hang.

use anyhow::{bail, Result};

use cnc_fl::cnc::optimize::{
    CohortStrategy, PartitionStrategy, PathStrategy, RbStrategy,
};
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::p2p::{self, P2pConfig};
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::{MockTrainer, Trainer};
use cnc_fl::model::params::ModelParams;
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::netsim::topology::CostMatrix;

/// A trainer that fails on a chosen client or after N calls.
struct FlakyTrainer {
    inner: MockTrainer,
    fail_on_client: Option<usize>,
    fail_after_calls: Option<usize>,
    calls: usize,
}

impl FlakyTrainer {
    fn new(n: usize, fail_on_client: Option<usize>, fail_after_calls: Option<usize>) -> Self {
        FlakyTrainer {
            inner: MockTrainer::new(n, 600),
            fail_on_client,
            fail_after_calls,
            calls: 0,
        }
    }
}

impl Trainer for FlakyTrainer {
    fn local_train(
        &mut self,
        client: usize,
        params: &ModelParams,
        epochs: usize,
        round: usize,
    ) -> Result<(ModelParams, f32)> {
        self.calls += 1;
        if Some(client) == self.fail_on_client {
            bail!("client {client} dropped out mid-training");
        }
        if let Some(n) = self.fail_after_calls {
            if self.calls > n {
                bail!("backend exhausted after {n} calls");
            }
        }
        self.inner.local_train(client, params, epochs, round)
    }

    fn evaluate(&mut self, params: &ModelParams) -> Result<f64> {
        self.inner.evaluate(params)
    }

    fn init_params(&self) -> Result<ModelParams> {
        self.inner.init_params()
    }

    fn data_size(&self, client: usize) -> usize {
        self.inner.data_size(client)
    }
}

fn system(n: usize) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 2;
    CncSystem::bootstrap(n, 600, 1, PowerProfile::Bimodal, ch, 0)
}

fn trad_cfg(rounds: usize, cohort: usize) -> TraditionalConfig {
    TraditionalConfig {
        rounds,
        cohort_size: cohort,
        n_rb: cohort,
        epoch_local: 1,
        cohort_strategy: CohortStrategy::Uniform,
        rb_strategy: RbStrategy::Random,
        eval_every: 1,
        tx_deadline_s: None,
        threads: 0,
        transport: Default::default(),
        seed: 0,
        verbose: false,
    }
}

#[test]
fn client_dropout_surfaces_as_error() {
    let mut sys = system(10);
    // cohort = whole fleet → client 3 is guaranteed to be hit
    let mut t = FlakyTrainer::new(10, Some(3), None);
    let err = traditional::run(&mut sys, &mut t, &trad_cfg(3, 10), "flaky")
        .unwrap_err()
        .to_string();
    assert!(err.contains("dropped out"), "{err}");
}

#[test]
fn backend_exhaustion_mid_run_is_propagated() {
    let mut sys = system(10);
    let mut t = FlakyTrainer::new(10, None, Some(12));
    // 5 clients/round → fails during round 3
    let res = traditional::run(&mut sys, &mut t, &trad_cfg(5, 5), "exhaust");
    assert!(res.is_err());
    assert!(res.unwrap_err().to_string().contains("exhausted"));
}

#[test]
fn p2p_chain_failure_propagates() {
    let mut sys = system(6);
    let mut t = FlakyTrainer::new(6, Some(2), None);
    let mut g = CostMatrix::new(6);
    for i in 0..6 {
        for j in 0..6 {
            if i != j {
                g.set(i, j, 1.0);
            }
        }
    }
    let cfg = P2pConfig {
        rounds: 2,
        partition_strategy: PartitionStrategy::All,
        path_strategy: PathStrategy::Greedy,
        epoch_local: 1,
        eval_every: 1,
        threads: 0,
        seed: 0,
        verbose: false,
        transport: Default::default(),
    };
    assert!(p2p::run(&mut sys, &mut t, &g, &cfg, "flaky").is_err());
}

#[test]
fn p2p_on_disconnected_topology_errors_not_hangs() {
    let mut sys = system(4);
    let mut t = MockTrainer::new(4, 600);
    // star graph: no Hamiltonian path over all 4
    let mut g = CostMatrix::new(4);
    g.set_sym(0, 1, 1.0);
    g.set_sym(0, 2, 1.0);
    g.set_sym(0, 3, 1.0);
    let cfg = P2pConfig {
        rounds: 1,
        partition_strategy: PartitionStrategy::All,
        path_strategy: PathStrategy::Greedy,
        epoch_local: 1,
        eval_every: 1,
        threads: 0,
        seed: 0,
        verbose: false,
        transport: Default::default(),
    };
    let err = p2p::run(&mut sys, &mut t, &g, &cfg, "star").unwrap_err();
    assert!(err.to_string().contains("no feasible path"), "{err}");
}

#[test]
fn p2p_wrong_topology_size_rejected() {
    let mut sys = system(5);
    let mut t = MockTrainer::new(5, 600);
    let g = CostMatrix::new(9); // wrong fleet size
    let cfg = P2pConfig {
        rounds: 1,
        partition_strategy: PartitionStrategy::All,
        path_strategy: PathStrategy::Greedy,
        epoch_local: 1,
        eval_every: 1,
        threads: 0,
        seed: 0,
        verbose: false,
        transport: Default::default(),
    };
    assert!(p2p::run(&mut sys, &mut t, &g, &cfg, "size").is_err());
}

#[test]
fn cohort_larger_than_fleet_rejected() {
    let mut sys = system(5);
    let mut t = MockTrainer::new(5, 600);
    let res = traditional::run(&mut sys, &mut t, &trad_cfg(1, 6), "big");
    assert!(res.is_err());
}

#[test]
fn failed_round_leaves_no_partial_bus_round() {
    // error during local training: the decision + broadcast were already
    // announced (that matches reality: the CNC published a strategy) but
    // the UpdatesCollected message must be absent
    let mut sys = system(10);
    let mut t = FlakyTrainer::new(10, Some(0), None);
    let _ = traditional::run(&mut sys, &mut t, &trad_cfg(1, 10), "partial");
    let msgs = sys.bus.round_messages(0);
    assert!(msgs.iter().all(|m| !matches!(
        m,
        cnc_fl::cnc::Announcement::UpdatesCollected { .. }
    )));
}

//! Failure-injection tests: the coordinator must surface backend errors
//! cleanly (no partial aggregation, no poisoned state), the CNC
//! decision layer must reject impossible topologies rather than hang,
//! and the fleet engine must survive hostile network weather — byzantine
//! payloads never reach the global model, outages are accounted, and
//! calm weather is bit-identical to a run with no weather machinery.

use anyhow::{bail, Result};

use cnc_fl::cnc::optimize::{CohortStrategy, PartitionStrategy, RbStrategy};
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::p2p::{self, P2pConfig};
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::{MockTrainer, Trainer};
use cnc_fl::fleet::{self, FleetConfig, GuardPolicy, WeatherSpec};
use cnc_fl::model::params::ModelParams;
use cnc_fl::model::shape::ModelShape;
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::netsim::topology::CostMatrix;

/// A trainer that fails on a chosen client or after N calls.
struct FlakyTrainer {
    inner: MockTrainer,
    fail_on_client: Option<usize>,
    fail_after_calls: Option<usize>,
    calls: usize,
}

impl FlakyTrainer {
    fn new(n: usize, fail_on_client: Option<usize>, fail_after_calls: Option<usize>) -> Self {
        FlakyTrainer {
            inner: MockTrainer::new(n, 600),
            fail_on_client,
            fail_after_calls,
            calls: 0,
        }
    }
}

impl Trainer for FlakyTrainer {
    fn local_train(
        &mut self,
        client: usize,
        params: &ModelParams,
        epochs: usize,
        round: usize,
    ) -> Result<(ModelParams, f32)> {
        self.calls += 1;
        if Some(client) == self.fail_on_client {
            bail!("client {client} dropped out mid-training");
        }
        if let Some(n) = self.fail_after_calls {
            if self.calls > n {
                bail!("backend exhausted after {n} calls");
            }
        }
        self.inner.local_train(client, params, epochs, round)
    }

    fn evaluate(&mut self, params: &ModelParams) -> Result<f64> {
        self.inner.evaluate(params)
    }

    fn init_params(&self) -> Result<ModelParams> {
        self.inner.init_params()
    }

    fn data_size(&self, client: usize) -> usize {
        self.inner.data_size(client)
    }
}

fn system(n: usize) -> CncSystem {
    let mut ch = ChannelParams::default();
    ch.fading_samples = 2;
    CncSystem::bootstrap(n, 600, 1, PowerProfile::Bimodal, ch, 0)
}

fn trad_cfg(rounds: usize, cohort: usize) -> TraditionalConfig {
    TraditionalConfig {
        rounds,
        cohort_size: cohort,
        n_rb: cohort,
        cohort_strategy: CohortStrategy::Uniform,
        rb_strategy: RbStrategy::Random,
        ..Default::default()
    }
}

#[test]
fn client_dropout_surfaces_as_error() {
    let mut sys = system(10);
    // cohort = whole fleet → client 3 is guaranteed to be hit
    let mut t = FlakyTrainer::new(10, Some(3), None);
    let err = traditional::run(&mut sys, &mut t, &trad_cfg(3, 10), "flaky")
        .unwrap_err()
        .to_string();
    assert!(err.contains("dropped out"), "{err}");
}

#[test]
fn backend_exhaustion_mid_run_is_propagated() {
    let mut sys = system(10);
    let mut t = FlakyTrainer::new(10, None, Some(12));
    // 5 clients/round → fails during round 3
    let res = traditional::run(&mut sys, &mut t, &trad_cfg(5, 5), "exhaust");
    assert!(res.is_err());
    assert!(res.unwrap_err().to_string().contains("exhausted"));
}

#[test]
fn p2p_chain_failure_propagates() {
    let mut sys = system(6);
    let mut t = FlakyTrainer::new(6, Some(2), None);
    let mut g = CostMatrix::new(6);
    for i in 0..6 {
        for j in 0..6 {
            if i != j {
                g.set(i, j, 1.0);
            }
        }
    }
    let cfg = P2pConfig {
        rounds: 2,
        partition_strategy: PartitionStrategy::All,
        ..Default::default()
    };
    assert!(p2p::run(&mut sys, &mut t, &g, &cfg, "flaky").is_err());
}

#[test]
fn p2p_on_disconnected_topology_errors_not_hangs() {
    let mut sys = system(4);
    let mut t = MockTrainer::new(4, 600);
    // star graph: no Hamiltonian path over all 4
    let mut g = CostMatrix::new(4);
    g.set_sym(0, 1, 1.0);
    g.set_sym(0, 2, 1.0);
    g.set_sym(0, 3, 1.0);
    let cfg = P2pConfig {
        rounds: 1,
        partition_strategy: PartitionStrategy::All,
        ..Default::default()
    };
    let err = p2p::run(&mut sys, &mut t, &g, &cfg, "star").unwrap_err();
    assert!(err.to_string().contains("no feasible path"), "{err}");
}

#[test]
fn p2p_wrong_topology_size_rejected() {
    let mut sys = system(5);
    let mut t = MockTrainer::new(5, 600);
    let g = CostMatrix::new(9); // wrong fleet size
    let cfg = P2pConfig {
        rounds: 1,
        partition_strategy: PartitionStrategy::All,
        ..Default::default()
    };
    assert!(p2p::run(&mut sys, &mut t, &g, &cfg, "size").is_err());
}

#[test]
fn cohort_larger_than_fleet_rejected() {
    let mut sys = system(5);
    let mut t = MockTrainer::new(5, 600);
    let res = traditional::run(&mut sys, &mut t, &trad_cfg(1, 6), "big");
    assert!(res.is_err());
}

#[test]
fn failed_round_leaves_no_partial_bus_round() {
    // error during local training: the decision + broadcast were already
    // announced (that matches reality: the CNC published a strategy) but
    // the UpdatesCollected message must be absent
    let mut sys = system(10);
    let mut t = FlakyTrainer::new(10, Some(0), None);
    let _ = traditional::run(&mut sys, &mut t, &trad_cfg(1, 10), "partial");
    let msgs = sys.bus.round_messages(0);
    assert!(msgs.iter().all(|m| !matches!(
        m,
        cnc_fl::cnc::Announcement::UpdatesCollected { .. }
    )));
}

// ---------------------------------------------------------------- fleet
// weather: the hostile-network gate for the async fleet engine

fn fleet_cfg(rounds: usize, shards: usize, max_staleness: usize) -> FleetConfig {
    FleetConfig {
        rounds,
        shards,
        max_staleness,
        cohort_size: 8,
        n_rb: 8,
        cohort_strategy: CohortStrategy::PowerGrouping { m: 5 },
        ..Default::default()
    }
}

#[test]
fn weather_runs_are_deterministic_per_seed() {
    for spec in ["byzantine:0.5", "flaky:0.3", "outage:1:2", "storm:6:2"] {
        let mut c = fleet_cfg(5, 4, 1);
        c.regions = 2;
        c.weather = spec.parse().unwrap();
        let csv_of = || {
            let mut s = system(40);
            let mut t = MockTrainer::new(40, 600);
            fleet::run(&mut s, &mut t, &c, "wx").unwrap().to_csv().to_string()
        };
        // identical seed → identical CSV, including the weather columns
        assert_eq!(csv_of(), csv_of(), "{spec}");
    }
}

#[test]
fn calm_weather_is_bitwise_identical_to_a_guardless_run() {
    // the weather machinery must be a strict no-op under calm skies: the
    // guard admits without touching values and calm draws no RNG, so a
    // guarded serial run, a guarded parallel run, and a guard-off run
    // all land on the same bits for every shape preset
    for name in ["mlp-small", "mlp-784", "mlp-wide"] {
        let shape = ModelShape::preset(name).unwrap();
        let run_one = |threads: usize, guard: GuardPolicy| {
            let mut s = system(40);
            let mut t = MockTrainer::with_shape(40, 600, &shape);
            let mut c = fleet_cfg(4, 4, 1);
            c.threads = threads;
            c.guard = guard;
            fleet::run_with_model(&mut s, &mut t, &c, "calm").unwrap()
        };
        let (h_ser, g_ser) = run_one(1, GuardPolicy::default());
        let (h_par, g_par) = run_one(4, GuardPolicy::default());
        let (h_off, g_off) = run_one(1, GuardPolicy::off());
        for (i, x) in g_ser.as_slice().iter().enumerate() {
            assert_eq!(x.to_bits(), g_par.as_slice()[i].to_bits(), "{name} ∥");
            assert_eq!(x.to_bits(), g_off.as_slice()[i].to_bits(), "{name} off");
        }
        let csv = h_ser.to_csv().to_string();
        assert_eq!(csv, h_par.to_csv().to_string(), "{name} ∥");
        assert_eq!(csv, h_off.to_csv().to_string(), "{name} off");
    }
}

#[test]
fn byzantine_updates_never_reach_the_global_model() {
    // every trained slot after round 0 is poisoned; the guard must drop
    // them all and the global model must stay finite every round
    let mut c = fleet_cfg(4, 2, 0);
    c.weather = "byzantine:1.0".parse().unwrap();
    let mut s = system(40);
    let mut t = MockTrainer::new(40, 600);
    let (h, g) = fleet::run_with_model(&mut s, &mut t, &c, "byz").unwrap();
    assert!(g.as_slice().iter().all(|v| v.is_finite()));
    assert_eq!(h.rounds[0].rejected_updates, 0); // baseline round is exempt
    let rejected: usize = h.rounds.iter().map(|r| r.rejected_updates).sum();
    assert_eq!(rejected, (h.rounds.len() - 1) * c.cohort_size);
    for r in &h.rounds {
        assert!(r.accuracy.is_finite());
        if r.round > 0 {
            assert_eq!(r.shards_committed, 0);
        }
    }
}

#[test]
fn guard_off_lets_byzantine_updates_poison_the_global() {
    // the defenseless control: with the guard disabled the same storm
    // corrupts the global model — this is what the guard is for
    let mut c = fleet_cfg(3, 2, 0);
    c.weather = "byzantine:1.0".parse().unwrap();
    c.guard = GuardPolicy::off();
    let mut s = system(40);
    let mut t = MockTrainer::new(40, 600);
    let (h, g) = fleet::run_with_model(&mut s, &mut t, &c, "byz-off").unwrap();
    assert_eq!(h.rounds.iter().map(|r| r.rejected_updates).sum::<usize>(), 0);
    assert!(g.as_slice().iter().any(|v| !v.is_finite() || v.abs() > 1e3));
}

#[test]
fn all_rejected_updates_keep_the_previous_global_bit_identical() {
    // clip so tight even honest updates bounce: the root keeps the
    // previous global verbatim, still emits a CSV row per round, counts
    // the whole cohort as rejected, and never reports NaN accuracy
    let mut c = fleet_cfg(3, 2, 0);
    c.guard = GuardPolicy {
        enabled: true,
        clip_norm: 1e-12,
        trim_frac: 0.0,
    };
    let mut s = system(40);
    let mut t = MockTrainer::new(40, 600);
    let (h, g) = fleet::run_with_model(&mut s, &mut t, &c, "reject-all").unwrap();
    let init = t.init_params().unwrap();
    for (x, y) in g.as_slice().iter().zip(init.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    assert_eq!(h.rounds.len(), 3);
    for r in &h.rounds {
        assert_eq!(r.shards_committed, 0);
        assert_eq!(r.rejected_updates, c.cohort_size);
        assert!(!r.accuracy.is_nan());
        assert_eq!(r.accuracy, 0.0);
    }
}

#[test]
fn outage_rounds_and_recovery_reach_the_csv() {
    let mut c = fleet_cfg(8, 4, 1);
    c.regions = 2;
    c.weather = "outage:1:2".parse().unwrap();
    let mut s = system(40);
    let mut t = MockTrainer::new(40, 600);
    let h = fleet::run(&mut s, &mut t, &c, "outage").unwrap();
    assert_eq!(h.rounds[0].outage_regions, 0); // round 0 is always clear
    assert!(h.rounds.iter().any(|r| r.outage_regions == 1));
    assert!(h.rounds.iter().any(|r| r.recovery_rounds > 0));
    let header = h.to_csv().to_string();
    let header = header.lines().next().unwrap().to_string();
    assert!(header.ends_with("rejected_updates,outage_regions,recovery_rounds"));
}

#[test]
fn malformed_weather_and_guard_specs_are_rejected() {
    for bad in [
        "", "gale", "outage:0:2", "outage:1:0", "outage:1", "byzantine:1.5",
        "byzantine", "flaky:-0.1", "flaky", "storm:0", "storm:4:0", "calm:1",
    ] {
        assert!(bad.parse::<WeatherSpec>().is_err(), "`{bad}` must not parse");
    }
    for bad in ["", "onn", "on:0", "on:nan", "on:1e6:0.5", "off:1"] {
        assert!(bad.parse::<GuardPolicy>().is_err(), "`{bad}` must not parse");
    }
}

//! Integration tests: the real PJRT engine over the AOT artifacts.
//!
//! These exercise the L3 ⇄ L2/L1 seam — loading the HLO text that
//! `python/compile/aot.py` produced, compiling it on the PJRT CPU client
//! and checking the numerics against what the Python/JAX side promised.
//!
//! They require `make artifacts`; if the artifacts are missing the tests
//! skip (so `cargo test` works in a fresh checkout).

use std::path::PathBuf;

use cnc_fl::data::batch::{epoch_batches, eval_chunks};
use cnc_fl::data::synth::{gen_dataset, gen_test_set, Prototypes, SynthSpec};

use cnc_fl::runtime::{ArtifactStore, Engine};
use cnc_fl::util::rng::Pcg64;

fn engine() -> Option<Engine> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::new(ArtifactStore::load(&dir).unwrap()).unwrap())
}

fn spec() -> (Prototypes, SynthSpec) {
    let spec = SynthSpec::default();
    (Prototypes::build(&spec), spec)
}

#[test]
fn train_step_runs_and_changes_params() {
    let Some(engine) = engine() else { return };
    let params = engine.store().init_params().unwrap();
    let (protos, s) = spec();
    let d = gen_dataset(&protos, &s, "it/step", 10, &[0, 1, 2]);
    let (next, loss) = engine
        .train_step(&params, &d.x, &d.y, 0.01)
        .unwrap();
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    assert!(next.max_abs_diff(&params) > 0.0, "params must move");
    // initial loss should be near ln(10) for random init
    assert!((1.0..4.0).contains(&loss), "loss={loss}");
}

#[test]
fn train_epoch_matches_sequential_train_steps() {
    let Some(engine) = engine() else { return };
    let params = engine.store().init_params().unwrap();
    let (protos, s) = spec();
    let d = gen_dataset(&protos, &s, "it/epoch", 600, &[0, 1, 2, 3]);
    let mut rng = Pcg64::seed_from(0);
    let b = epoch_batches(&d, 10, &mut rng);

    // scan path
    let (scan_params, scan_loss) = engine
        .train_epoch("train_epoch_600", &params, &b.x, &b.y, b.num_batches, 0.01)
        .unwrap();

    // per-batch path
    let mut cur = params.clone();
    let mut losses = Vec::new();
    for i in 0..b.num_batches {
        let x = &b.x[i * 10 * 784..(i + 1) * 10 * 784];
        let y = &b.y[i * 10..(i + 1) * 10];
        let (next, loss) = engine.train_step(&cur, x, y, 0.01).unwrap();
        cur = next;
        losses.push(loss);
    }
    let mean_loss = losses.iter().sum::<f32>() / losses.len() as f32;

    assert!(
        scan_params.max_abs_diff(&cur) < 1e-4,
        "scan vs stepwise diverged: {}",
        scan_params.max_abs_diff(&cur)
    );
    assert!((scan_loss - mean_loss).abs() < 1e-4);
}

#[test]
fn local_training_reduces_loss() {
    let Some(engine) = engine() else { return };
    let mut params = engine.store().init_params().unwrap();
    let (protos, s) = spec();
    let d = gen_dataset(&protos, &s, "it/reduce", 600, &[0, 1, 2]);
    let mut rng = Pcg64::seed_from(1);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..3 {
        let b = epoch_batches(&d, 10, &mut rng);
        let (next, loss) = engine
            .train_epoch("train_epoch_600", &params, &b.x, &b.y, b.num_batches, 0.05)
            .unwrap();
        params = next;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    assert!(
        last < 0.7 * first.unwrap(),
        "loss did not fall: {first:?} → {last}"
    );
}

#[test]
fn eval_chunk_counts_match_predictions() {
    let Some(engine) = engine() else { return };
    let params = engine.store().init_params().unwrap();
    let (protos, s) = spec();
    let test = gen_test_set(&protos, &s);
    let chunks = eval_chunks(&test, 1000);
    let correct = engine
        .eval_chunk(
            "eval_1000",
            &params,
            &chunks.chunks_x[0],
            &chunks.chunks_y[0],
            1000,
        )
        .unwrap();
    // untrained model: correct count plausible (0..~400 of 1000)
    assert!((0..=400).contains(&correct), "correct={correct}");
}

#[test]
fn predict_agrees_with_eval() {
    let Some(engine) = engine() else { return };
    let params = engine.store().init_params().unwrap();
    let (protos, s) = spec();
    let d = gen_dataset(&protos, &s, "it/pred", 100, &(0..10).collect::<Vec<_>>());
    let preds = engine.predict("predict_100", &params, &d.x, 100).unwrap();
    assert_eq!(preds.len(), 100);
    assert!(preds.iter().all(|&c| (0..10).contains(&c)));
    // predictions vary (not a constant classifier)
    let mut uniq = preds.clone();
    uniq.sort();
    uniq.dedup();
    assert!(uniq.len() > 1);
}

#[test]
fn engine_caches_compiles() {
    let Some(engine) = engine() else { return };
    let params = engine.store().init_params().unwrap();
    let (protos, s) = spec();
    let d = gen_dataset(&protos, &s, "it/cache", 10, &[0]);
    engine.train_step(&params, &d.x, &d.y, 0.01).unwrap();
    engine.train_step(&params, &d.x, &d.y, 0.01).unwrap();
    engine.train_step(&params, &d.x, &d.y, 0.01).unwrap();
    let stats = engine.stats();
    assert_eq!(stats.compile_count, 1, "executable must be cached");
    assert_eq!(stats.executions, 3);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(engine) = engine() else { return };
    let params = engine.store().init_params().unwrap();
    let x = vec![0.0f32; 5 * 784]; // wrong batch
    let y = vec![0i32; 5];
    assert!(engine.train_step(&params, &x, &y, 0.01).is_err());
}

#[test]
fn train_step_deterministic_across_executions() {
    let Some(engine) = engine() else { return };
    let params = engine.store().init_params().unwrap();
    let (protos, s) = spec();
    let d = gen_dataset(&protos, &s, "it/det", 10, &[4, 5]);
    let (a, la) = engine.train_step(&params, &d.x, &d.y, 0.01).unwrap();
    let (b, lb) = engine.train_step(&params, &d.x, &d.y, 0.01).unwrap();
    assert_eq!(la, lb);
    assert_eq!(a.max_abs_diff(&b), 0.0);
}

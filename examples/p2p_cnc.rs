//! Peer-to-peer showcase — the paper's Algorithm 2/3 on the real PJRT
//! path: a 20-client chain-training fleet under the four experiment-1
//! settings (CNC E=4, CNC E=2, random-15, all-20), reporting accuracy vs
//! the two consumption axes of Fig 9.
//!
//! ```sh
//! cargo run --release --example p2p_cnc [rounds]
//! ```

use anyhow::Result;

use cnc_fl::data::Split;
use cnc_fl::exp::figures::FigOpts;
use cnc_fl::exp::p2p_figs::{experiment1_settings, run_p2p_setting};
use cnc_fl::exp::presets::Backend;
use cnc_fl::metrics::Metric;
use cnc_fl::netsim::topology::TopologyGen;

fn main() -> Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);

    println!("== peer-to-peer architecture: experiment 1 (20 clients, {rounds} rounds) ==");
    println!("designed 20-client consumption matrix, Algorithm 3 path selection\n");

    let g = TopologyGen::designed_20(0);
    let opts = FigOpts {
        rounds: Some(rounds),
        backend: Backend::Pjrt,
        seed: 0,
        out_dir: "results".into(),
        verbose: false,
    };

    println!(
        "{:<10} {:>9} {:>16} {:>14} {:>12}",
        "setting", "accuracy", "chain_delay(s)", "path_cost", "clients/rnd"
    );
    for s in experiment1_settings() {
        let clients_per_round = match s.tag {
            "random15" => 15,
            _ => 20,
        };
        let h = run_p2p_setting(20, &g, &s, Split::Iid, rounds, &opts)?;
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        println!(
            "{:<10} {:>9.4} {:>16.2} {:>14.2} {:>12}",
            s.tag,
            h.final_accuracy(),
            mean(&h.series(Metric::LocalDelayRound)),
            mean(&h.series(Metric::TxEnergyRound)),
            clients_per_round,
        );
        h.write_csv(std::path::Path::new(&format!(
            "results/example_p2p_{}.csv",
            s.tag
        )))?;
    }

    println!(
        "\nreading: CNC E=4 parallel chains cut the straggler chain delay \
         (~4× shorter than all-20) at a modest path-cost premium — Fig 9's story."
    );
    println!("wrote results/example_p2p_<setting>.csv");
    Ok(())
}

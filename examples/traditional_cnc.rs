//! Traditional-architecture showcase: CNC optimization vs FedAvg, side by
//! side on the real PJRT path — the scenario behind the paper's Figs 6–8
//! and its headline claims (delay-difference ≈ 1/5, lower tx latency and
//! energy).
//!
//! ```sh
//! cargo run --release --example traditional_cnc [rounds]
//! ```

use anyhow::Result;

use cnc_fl::cnc::optimize::{CohortStrategy, RbStrategy};
use cnc_fl::coordinator::traditional;
use cnc_fl::data::Split;
use cnc_fl::exp::presets::{self, case, Method};
use cnc_fl::metrics::{Metric, RunHistory};
use cnc_fl::util::stats;

fn run_method(method: Method, rounds: usize) -> Result<RunHistory> {
    let c = case("Pr1")?;
    let mut cfg = presets::traditional_config(&c, method, Some(rounds), 0);
    cfg.eval_every = 2;
    let mut sys = presets::bootstrap_case(&c, 0);
    let mut trainer =
        presets::make_trainer(&presets::Backend::Pjrt, &c, Split::Iid, 0, None)?;
    traditional::run(&mut sys, trainer.as_mut(), &cfg, method.label())
}

fn main() -> Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);

    println!("== traditional architecture: CNC vs FedAvg (Pr1, IID, {rounds} rounds) ==\n");
    println!("CNC   = Algorithm 1 cohorts + Hungarian RB allocation (Eq 5)");
    println!(
        "FedAvg = uniform cohorts + random RBs  (strategies: {:?} / {:?})\n",
        CohortStrategy::Uniform,
        RbStrategy::Random
    );

    let h_cnc = run_method(Method::Cnc, rounds)?;
    let h_avg = run_method(Method::FedAvg, rounds)?;

    let mean = |v: &[f64]| stats::mean(v);
    let rows: [(&str, Box<dyn Fn(&RunHistory) -> f64>); 5] = [
        ("final accuracy", Box::new(|h: &RunHistory| h.final_accuracy())),
        (
            "mean local-delay diff (s)  [Fig 8]",
            Box::new(|h: &RunHistory| mean(&h.delay_diffs())),
        ),
        (
            "max  local-delay diff (s)",
            Box::new(|h: &RunHistory| stats::max(&h.delay_diffs())),
        ),
        (
            "mean round tx delay (s)    [Fig 6]",
            Box::new(|h: &RunHistory| mean(&h.series(Metric::TxDelayRound))),
        ),
        (
            "mean round tx energy (J)   [Fig 6]",
            Box::new(|h: &RunHistory| mean(&h.series(Metric::TxEnergyRound))),
        ),
    ];

    println!("{:<38} {:>12} {:>12} {:>10}", "metric", "CNC", "FedAvg", "ratio");
    for (name, f) in &rows {
        let a = f(&h_cnc);
        let b = f(&h_avg);
        println!(
            "{name:<38} {a:>12.4} {b:>12.4} {:>10.3}",
            if b != 0.0 { a / b } else { f64::NAN }
        );
    }
    println!(
        "\npaper claims (full 300-round horizon): delay-diff ratio ≈ 0.20, \
         max ≈ 0.466, tx latency ≈ 0.531, energy ≈ 0.806"
    );

    h_cnc.write_csv(std::path::Path::new("results/example_traditional_cnc.csv"))?;
    h_avg.write_csv(std::path::Path::new("results/example_traditional_fedavg.csv"))?;
    println!("wrote results/example_traditional_{{cnc,fedavg}}.csv");
    Ok(())
}

//! Engine stress probe — leak/perf diagnostics for the PJRT runtime.
//!
//! Loops a single artifact execution and prints RSS every N iterations so
//! memory growth can be attributed to a specific call path (this is the
//! tool that isolated the `execute::<Literal>` input-buffer leak in the
//! vendored crate's C++ shim — see EXPERIMENTS.md §Perf).
//!
//! ```sh
//! cargo run --release --example stress_engine [train_epoch|train_step|eval] [iters]
//! ```

use anyhow::Result;

use cnc_fl::data::batch::{epoch_batches, eval_chunks};
use cnc_fl::data::synth::{gen_dataset, gen_test_set, Prototypes, SynthSpec};
use cnc_fl::runtime::{ArtifactStore, Engine};
use cnc_fl::util::rng::Pcg64;

fn rss_mb() -> f64 {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches(" kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

fn main() -> Result<()> {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "train_epoch".into());
    let iters: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let engine = Engine::new(ArtifactStore::load(&ArtifactStore::default_dir())?)?;
    let params = engine.store().init_params()?;
    let spec = SynthSpec::default();
    let protos = Prototypes::build(&spec);

    println!("mode={mode} iters={iters}");
    let report_every = (iters / 10).max(1);

    match mode.as_str() {
        "train_epoch" => {
            let d = gen_dataset(&protos, &spec, "stress", 600, &[0, 1, 2]);
            let mut rng = Pcg64::seed_from(0);
            let b = epoch_batches(&d, 10, &mut rng);
            for i in 0..iters {
                let _ = engine.train_epoch(
                    "train_epoch_600",
                    &params,
                    &b.x,
                    &b.y,
                    b.num_batches,
                    0.01,
                )?;
                if i % report_every == 0 {
                    println!("iter {i:>6}  rss {:.0} MB", rss_mb());
                }
            }
        }
        "train_step" => {
            let d = gen_dataset(&protos, &spec, "stress", 10, &[0, 1]);
            for i in 0..iters {
                let _ = engine.train_step(&params, &d.x, &d.y, 0.01)?;
                if i % report_every == 0 {
                    println!("iter {i:>6}  rss {:.0} MB", rss_mb());
                }
            }
        }
        "eval" => {
            let t = gen_test_set(&protos, &spec);
            let ch = eval_chunks(&t, 1000);
            for i in 0..iters {
                let _ = engine.eval_chunk(
                    "eval_1000",
                    &params,
                    &ch.chunks_x[0],
                    &ch.chunks_y[0],
                    1000,
                )?;
                if i % report_every == 0 {
                    println!("iter {i:>6}  rss {:.0} MB", rss_mb());
                }
            }
        }
        other => anyhow::bail!("unknown mode {other}"),
    }
    let s = engine.stats();
    println!(
        "done: {} execs, {:.2}s exec wall, final rss {:.0} MB",
        s.executions,
        s.exec_wall_s,
        rss_mb()
    );
    Ok(())
}

//! Quickstart: the end-to-end driver proving all three layers compose.
//!
//! Scenario 1 boots the CNC stack and runs a short Pr1-style federated
//! training on the synthetic MNIST-like workload **through the real PJRT
//! path** (Rust coordinator → AOT HLO artifacts → JAX model → Pallas
//! kernels), logs the accuracy/loss curve, then classifies fresh samples
//! with the trained global model. (Skipped with a note when the
//! artifacts are absent — run `make artifacts` first.)
//!
//! Scenario 2 drives the **fleet engine** (`shards = 4`,
//! `max_staleness = 2`) over a 200-client mock fleet, printing the
//! per-shard delay spread next to the flat run's t_diff column — the
//! sharded/async analogue of the same round loop.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart [rounds]
//! ```

use anyhow::Result;

use cnc_fl::cnc::optimize::CohortStrategy;
use cnc_fl::cnc::CncSystem;
use cnc_fl::coordinator::traditional::{self, TraditionalConfig};
use cnc_fl::coordinator::{MockTrainer, PjrtTrainer};
use cnc_fl::data::synth::gen_dataset;
use cnc_fl::data::{Partition, Prototypes, Split, SynthSpec};
use cnc_fl::fleet::{self, FleetConfig, ShardBy};
use cnc_fl::netsim::channel::ChannelParams;
use cnc_fl::netsim::compute::PowerProfile;
use cnc_fl::runtime::{ArtifactStore, Engine};

fn main() -> Result<()> {
    let rounds: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    println!("== cnc-fl quickstart ==");
    println!("loading AOT artifacts (python built these once; no python now)");
    // only a failed *load* downgrades to a skip — a mid-training PJRT
    // error is a real regression and must propagate
    match ArtifactStore::load(&ArtifactStore::default_dir()) {
        Ok(store) => pjrt_scenario(store, rounds)?,
        Err(e) => {
            println!("(PJRT scenario skipped: {e:#} — run `make artifacts`)");
        }
    }
    fleet_scenario(rounds)
}

/// Scenario 1: the paper-fidelity PJRT path (needs the AOT artifacts).
fn pjrt_scenario(store: ArtifactStore, rounds: usize) -> Result<()> {
    println!(
        "  {} artifacts, {}-param model, batch size {}",
        store.artifacts.len(),
        store.param_count(),
        store.batch_size
    );
    let engine = Engine::new(store)?;

    // fleet: 100 clients, the paper's Pr1 (cfraction 0.1, 1 local epoch)
    let num_clients = 100;
    let spec = SynthSpec::default();
    let partition = Partition::new(num_clients, Split::Iid, 0);
    let mut trainer = PjrtTrainer::new(engine, partition, spec.clone(), 0.01, 0)?;
    trainer.warmup()?;

    let mut sys = CncSystem::bootstrap(
        num_clients,
        600,
        1,
        PowerProfile::Bimodal,
        ChannelParams::default(),
        0,
    );
    let cfg = TraditionalConfig {
        rounds,
        ..Default::default()
    };
    println!("\ntraining {rounds} global rounds (Pr1, CNC optimization, IID) …");
    let (h, global) =
        traditional::run_with_model(&mut sys, &mut trainer, &cfg, "quickstart")?;

    println!("\nround  accuracy  train_loss  t_diff(s)  tx_energy(J)");
    for r in &h.rounds {
        println!(
            "{:>5}  {:>8.4}  {:>10.4}  {:>9.3}  {:>12.5}",
            r.round,
            r.accuracy,
            r.train_loss,
            r.local_delay_diff_s(),
            r.tx_energy_round_j()
        );
    }
    println!("\nfinal test accuracy: {:.4}", h.final_accuracy());
    let stats = trainer.engine().stats();
    println!(
        "PJRT: {} executions, {:.2}s exec wall, {} compiles ({:.2}s)",
        stats.executions, stats.exec_wall_s, stats.compile_count, stats.compile_wall_s
    );

    // classify fresh samples with the trained model (Pallas forward pass)
    let protos = Prototypes::build(&spec);
    let demo = gen_dataset(
        &protos,
        &spec,
        "quickstart/demo",
        100,
        &(0..10).collect::<Vec<_>>(),
    );
    let preds = trainer
        .engine()
        .predict("predict_100", &global, &demo.x, 100)?;
    let correct = preds
        .iter()
        .zip(&demo.y)
        .filter(|(p, y)| p == y)
        .count();
    println!("\nfresh-sample classification: {correct}/100 correct");
    println!("  first 10 predictions: {:?}", &preds[..10]);
    println!("  first 10 labels:      {:?}", &demo.y[..10]);

    let out = std::path::Path::new("results/quickstart.csv");
    h.write_csv(out)?;
    println!("\nwrote {}", out.display());
    Ok(())
}

/// Scenario 2: the fleet engine — sharded decisions, hierarchical
/// aggregation, bounded-staleness commits (mock backend, no artifacts).
fn fleet_scenario(rounds: usize) -> Result<()> {
    let num_clients = 200;
    println!(
        "\n== fleet engine: {num_clients} clients, 4 shards / 2 regions, \
         max_staleness 2 =="
    );
    let mut sys = CncSystem::bootstrap(
        num_clients,
        600,
        1,
        PowerProfile::Bimodal,
        ChannelParams::default(),
        0,
    );
    let mut trainer = MockTrainer::new(num_clients, 600);
    let cfg = FleetConfig {
        rounds,
        shards: 4,
        shard_by: ShardBy::Power,
        regions: 2,
        max_staleness: 2,
        staleness_decay: 0.5,
        cohort_size: 20,
        n_rb: 20,
        cohort_strategy: CohortStrategy::PowerGrouping { m: 10 },
        seed: 0,
        ..Default::default()
    };
    let h = fleet::run(&mut sys, &mut trainer, &cfg, "quickstart-fleet")?;

    println!("\nround  accuracy  train_loss  shards  stale  shard_spread_max(s)");
    for r in &h.rounds {
        println!(
            "{:>5}  {:>8.4}  {:>10.4}  {:>6}  {:>5.2}  {:>19.3}",
            r.round,
            r.accuracy,
            r.train_loss,
            r.shards_committed,
            r.staleness_mean,
            r.shard_spread_max_s()
        );
    }
    let commits: usize = h.rounds.iter().map(|r| r.shards_committed).sum();
    println!(
        "\nfleet final accuracy: {:.4} ({commits} shard commits over {} rounds)",
        h.final_accuracy(),
        h.rounds.len()
    );
    let out = std::path::Path::new("results/quickstart_fleet.csv");
    h.write_csv(out)?;
    println!("wrote {}", out.display());
    Ok(())
}

//! Scaling study (the paper's Fig 11): how the average global-round
//! latency grows with the fleet size under
//!   * CNC optimization (balanced E=4 partition + Algorithm 3 paths),
//!   * a single greedy chain over everyone, and
//!   * a single exact-TSP chain (n ≤ 20 — Held–Karp's tractability wall).
//!
//! Latency is the simulated quantity (Eq 8 local delays + path costs), so
//! this uses the mock training backend — the scheduling decisions are the
//! real thing.
//!
//! ```sh
//! cargo run --release --example scaling_study
//! ```

use anyhow::Result;

use cnc_fl::exp::figures::FigOpts;
use cnc_fl::exp::p2p_figs::fig11;
use cnc_fl::exp::presets::Backend;

fn main() -> Result<()> {
    let sizes = [8usize, 12, 16, 20, 24, 28, 32];
    println!("== scaling study: avg global-round latency vs fleet size (Fig 11) ==\n");

    let opts = FigOpts {
        rounds: Some(5),
        backend: Backend::Mock,
        seed: 0,
        out_dir: "results".into(),
        verbose: false,
    };
    let path = fig11(&opts, &sizes)?;
    let text = std::fs::read_to_string(&path)?;

    println!("{:<12} {:>14} {:>16} {:>12}", "clients", "CNC E=4 (s)", "all-chain (s)", "TSP (s)");
    for line in text.lines().skip(1) {
        let cells: Vec<&str> = line.split(',').collect();
        let fmt = |s: &str| {
            s.parse::<f64>()
                .map(|x| if x.is_nan() { "—".to_string() } else { format!("{x:.1}") })
                .unwrap_or_else(|_| "—".to_string())
        };
        println!(
            "{:<12} {:>14} {:>16} {:>12}",
            cells[0],
            fmt(cells[1]),
            fmt(cells[2]),
            fmt(cells[3])
        );
    }
    println!(
        "\nreading: the CNC's parallel balanced chains keep the latency \
         growth rate far below the serial chain (the paper's Fig 11 claim); \
         exact TSP helps path cost but cannot fix the serial-chain latency \
         and stops scaling at n = 20."
    );
    println!("\nwrote {}", path.display());
    Ok(())
}

"""Reference measurement seeding ``rust/BENCH_codec.json``.

The rust binary (``cargo run --release --bin bench_codec``) is the
authoritative generator of the codec-fold perf artifact; this numpy
script reproduces its exact workload — decode-then-fold vs
encoded-domain fold over the same pre-encoded payload pool — for
environments without a Rust toolchain, and labels its output
``"backend": "python-reference"`` so nobody mistakes the numbers for
the engine's. CI regenerates the artifact with the rust binary
(``"backend": "rust"``) and validates the same schema and acceptance
bar (encoded <= decode-then-fold at 10^4 commits for quant8/topk0.1).

Workload (mirrors ``rust/src/bin/bench_codec.rs`` --quick):

* shape ``mlp-small`` (784 -> 32 -> 10: tensors of 25088/32/320/10 f32)
* a pool of 64 gaussian updates cycled to 10^3 / 10^4 commits
* quant8: per-tensor affine u8 grid. Baseline dequantizes every payload
  into a dense scratch then folds; the encoded fold does
  ``acc += (w*scale)*codes`` + a per-tensor f64 bias, one dequantize at
  finish.
* topk0.1: per-tensor top-10% magnitude entries. Baseline densifies
  into scratch then folds the full arena; the encoded fold scatter-adds
  only the kept entries.
* raw: both paths are the same dense fold (a noise floor).

Run from the repo root:  python3 python/bench/bench_codec_reference.py
"""

import json
import math
import time
from pathlib import Path

import numpy as np

TENSORS = [784 * 32, 32, 320, 10]  # mlp-small
POOL = 64
WEIGHT = 600
COMMIT_COUNTS = [1_000, 10_000]
KEEP_FRAC = 0.1

MIN_ITERS = 3
MIN_TIME_S = 0.3
MAX_ITERS = 50


def bench(fn):
    """Median ns/iter, Bencher::coarse()-style (warmup, then >=3 iters
    and >=0.3 s)."""
    fn()  # warmup
    samples = []
    start = time.perf_counter()
    while (len(samples) < MIN_ITERS or time.perf_counter() - start < MIN_TIME_S) \
            and len(samples) < MAX_ITERS:
        t0 = time.perf_counter()
        fn()
        samples.append((time.perf_counter() - t0) * 1e9)
    return float(np.median(samples))


def keep_count(n, frac):
    return max(1, min(n, math.ceil(n * frac - 1e-6)))


def make_pool(rng):
    return [
        [rng.normal(0.0, 0.05, size=n).astype(np.float32) for n in TENSORS]
        for _ in range(POOL)
    ]


def quantize8(update):
    grids = []
    for t in update:
        lo, hi = float(t.min()), float(t.max())
        scale = (hi - lo) / 255.0 if hi > lo else 1.0
        codes = np.clip(np.rint((t - lo) / scale), 0, 255).astype(np.uint8)
        grids.append((codes, np.float32(lo), np.float32(scale)))
    return grids


def sparsify_topk(update):
    kept = []
    for t in update:
        k = keep_count(t.size, KEEP_FRAC)
        idx = np.argpartition(-np.abs(t), k - 1)[:k]
        idx = np.sort(idx).astype(np.uint32)
        kept.append((idx, t[idx]))
    return kept


def fold_raw(pool, commits):
    acc = [np.zeros(n, dtype=np.float32) for n in TENSORS]
    w = np.float32(WEIGHT)
    for i in range(commits):
        for a, t in zip(acc, pool[i % POOL]):
            a += w * t
    inv = np.float32(1.0 / (WEIGHT * commits))
    return [a * inv for a in acc]


def fold_quant8_decode(encoded, commits):
    acc = [np.zeros(n, dtype=np.float32) for n in TENSORS]
    scratch = [np.empty(n, dtype=np.float32) for n in TENSORS]
    w = np.float32(WEIGHT)
    for i in range(commits):
        for a, s, (codes, lo, scale) in zip(acc, scratch, encoded[i % POOL]):
            np.multiply(codes, scale, out=s, dtype=np.float32)
            s += lo
            a += w * s
    inv = np.float32(1.0 / (WEIGHT * commits))
    return [a * inv for a in acc]


def fold_quant8_encoded(encoded, commits):
    acc = [np.zeros(n, dtype=np.float32) for n in TENSORS]
    bias = [0.0] * len(TENSORS)
    for i in range(commits):
        for t, (a, (codes, lo, scale)) in enumerate(zip(acc, encoded[i % POOL])):
            a += np.float32(WEIGHT * scale) * codes
            bias[t] += WEIGHT * float(lo)
    inv = 1.0 / (WEIGHT * commits)
    return [((a.astype(np.float64) + b) * inv).astype(np.float32)
            for a, b in zip(acc, bias)]


def fold_topk_decode(encoded, commits):
    acc = [np.zeros(n, dtype=np.float32) for n in TENSORS]
    scratch = [np.empty(n, dtype=np.float32) for n in TENSORS]
    w = np.float32(WEIGHT)
    for i in range(commits):
        for a, s, (idx, vals) in zip(acc, scratch, encoded[i % POOL]):
            s.fill(0.0)
            s[idx] = vals
            a += w * s
    inv = np.float32(1.0 / (WEIGHT * commits))
    return [a * inv for a in acc]


def fold_topk_encoded(encoded, commits):
    acc = [np.zeros(n, dtype=np.float32) for n in TENSORS]
    w = np.float32(WEIGHT)
    for i in range(commits):
        for a, (idx, vals) in zip(acc, encoded[i % POOL]):
            a[idx] += w * vals  # indices are unique per payload
    inv = np.float32(1.0 / (WEIGHT * commits))
    return [a * inv for a in acc]


def main():
    rng = np.random.default_rng(0xC0DEC)
    pool = make_pool(rng)
    q8 = [quantize8(u) for u in pool]
    topk = [sparsify_topk(u) for u in pool]

    rows = []
    for commits in COMMIT_COUNTS:
        raw_ns = bench(lambda c=commits: fold_raw(pool, c))
        rows.append({
            "commits": commits, "codec": "raw",
            "bytes_per_round": commits * sum(TENSORS) * 4,
            "decode_fold_ns": round(raw_ns, 1),
            "encoded_fold_ns": round(raw_ns, 1),
            "speedup": 1.0,
        })
        q_dec = bench(lambda c=commits: fold_quant8_decode(q8, c))
        q_enc = bench(lambda c=commits: fold_quant8_encoded(q8, c))
        rows.append({
            "commits": commits, "codec": "quant8",
            "bytes_per_round": commits * (sum(TENSORS) + len(TENSORS) * 8),
            "decode_fold_ns": round(q_dec, 1),
            "encoded_fold_ns": round(q_enc, 1),
            "speedup": round(q_dec / q_enc, 3),
        })
        t_dec = bench(lambda c=commits: fold_topk_decode(topk, c))
        t_enc = bench(lambda c=commits: fold_topk_encoded(topk, c))
        kept = sum(keep_count(n, KEEP_FRAC) for n in TENSORS)
        rows.append({
            "commits": commits, "codec": "topk0.1",
            "bytes_per_round": commits * (kept * 8 + len(TENSORS) * 4),
            "decode_fold_ns": round(t_dec, 1),
            "encoded_fold_ns": round(t_enc, 1),
            "speedup": round(t_dec / t_enc, 3),
        })
        for r in rows[-3:]:
            print(f"{r['commits']:>6} commits  {r['codec']:<8} "
                  f"decode+fold {r['decode_fold_ns'] / 1e6:10.2f} ms  "
                  f"encoded {r['encoded_fold_ns'] / 1e6:10.2f} ms  "
                  f"{r['speedup']:.2f}x")

    doc = {
        "bench": "codec",
        "backend": "python-reference",
        "note": ("numpy reference measurement of the bench_codec workload; "
                 "CI regenerates this artifact with "
                 "`cargo run --release --bin bench_codec -- --quick` "
                 "(backend: rust)"),
        "shape": "mlp-small",
        "weight": WEIGHT,
        "pool": POOL,
        "rows": rows,
    }
    out = Path(__file__).resolve().parents[2] / "rust" / "BENCH_codec.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

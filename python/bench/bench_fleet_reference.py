"""Reference measurement seeding ``rust/BENCH_fleet.json``.

The rust bench (``cargo bench --bench bench_fleet``) is the
authoritative generator of the engine-driver perf artifact; this numpy
script reproduces its workload shape — fixed-cohort fleet rounds at
10^3..10^6 clients under the loop driver, the event driver, and the
event driver with diurnal arrival waves — for environments without a
Rust toolchain, and labels its output ``"backend": "python-reference"``
so nobody mistakes the numbers for the engine's. CI validates the same
schema and acceptance bar against whichever backend produced the file:
the event driver's per-round cost grows <= ~2x from 10^5 to 10^6
clients at a fixed cohort (the round's work tracks the cohort, not the
fleet).

Workload (mirrors the ``engine drivers`` section of
``rust/benches/bench_fleet.rs``):

* 128 shards, cohort 512 split proportionally across them, 10 rounds,
  ``mlp-784``-sized updates (203,530 f32 params)
* per round, each engine touches only its started shards: uniform
  cohort selection over the shard stratum index range (O(cohort_s)),
  a mock local step per cohort member (scaled gradient toward a
  target on the shared arena), and a shard fold + root merge
* the loop driver starts every idle shard each round; the event driver
  is identical with waves degenerate (``Always``); ``event-diurnal``
  wakes each shard only inside its seeded diurnal window
  (period 5, window fraction drawn from [0.3, 0.6)), so asleep shards
  are never touched — their strata stay unmaterialized
* registry strata materialize lazily: a shard's delay/distance view is
  built on first touch and cached, so fleet size prices the first
  round, not every round

Run from the repo root:  python3 python/bench/bench_fleet_reference.py
"""

import json
import time
from pathlib import Path

import numpy as np

PARAMS = 784 * 256 + 256 + 256 * 10 + 10  # mlp-784: 203,530
SHARDS = 128
COHORT = 512
ROUNDS = 10
CLIENT_COUNTS = [1_000, 10_000, 100_000, 1_000_000]
RATE = np.float32(0.3)
SEED = 0xF1EE7


def split_proportional(total, sizes):
    """Largest-remainder proportional split (mirrors the registry's)."""
    fleet = sum(sizes)
    quotas = [total * s / fleet for s in sizes]
    out = [int(q) for q in quotas]
    rest = total - sum(out)
    order = sorted(range(len(sizes)), key=lambda i: (out[i] - quotas[i], i))
    for i in order[:rest]:
        out[i] += 1
    return out


def diurnal_windows(rng, shards, period, floor, peak):
    offsets = rng.integers(0, period, size=shards)
    frac = rng.uniform(floor, peak, size=shards)
    windows = np.clip(np.rint(period * frac), 1, period).astype(int)
    return offsets, windows


def run_engine(clients, engine, rng):
    """One fleet run; returns (elapsed_s, shard_commits)."""
    sizes = [clients // SHARDS] * SHARDS
    for i in range(clients % SHARDS):
        sizes[i] += 1
    cohorts = split_proportional(COHORT, sizes)
    if engine == "event-diurnal":
        offsets, windows = diurnal_windows(rng, SHARDS, 5, 0.3, 0.6)
    global_model = np.zeros(PARAMS, dtype=np.float32)
    strata = {}  # shard -> materialized view (lazy, cached)
    commits = 0
    t0 = time.perf_counter()
    for rnd in range(ROUNDS):
        partials = []
        for s in range(SHARDS):
            if cohorts[s] == 0:
                continue
            if engine == "event-diurnal" and \
                    (rnd + offsets[s]) % 5 >= windows[s]:
                continue  # asleep: the shard is never touched
            if s not in strata:
                # first touch materializes the shard's stratum view
                strata[s] = rng.normal(1.0, 0.2, size=sizes[s]) \
                    .astype(np.float32)
            view = strata[s]
            cohort = rng.integers(0, sizes[s], size=cohorts[s])
            acc = np.zeros(PARAMS, dtype=np.float32)
            for c in cohort:
                # mock local step: move toward the target on the arena
                step = RATE * np.float32(view[c]) * \
                    (np.float32(1.0) - global_model)
                acc += step
            partials.append((acc, cohorts[s]))
            commits += 1
        if partials:
            weight = sum(w for _, w in partials)
            folded = np.zeros(PARAMS, dtype=np.float32)
            for acc, w in partials:
                folded += acc * np.float32(w)
            global_model = global_model + folded / np.float32(weight * COHORT)
    return time.perf_counter() - t0, commits


def main():
    rows = []
    for clients in CLIENT_COUNTS:
        for engine in ("loop", "event", "event-diurnal"):
            rng = np.random.default_rng(SEED)
            elapsed, commits = run_engine(clients, engine, rng)
            per_round_ms = elapsed * 1e3 / ROUNDS
            rows.append({
                "clients": clients, "shards": SHARDS, "cohort": COHORT,
                "engine": engine, "rounds": ROUNDS,
                "shard_commits": commits,
                "per_round_ms": round(per_round_ms, 3),
            })
            print(f"{clients:>9} clients  {engine:<13} "
                  f"{commits:>5} commits  {per_round_ms:10.2f} ms/round")

    doc = {
        "bench": "fleet_engine",
        "backend": "python-reference",
        "note": ("numpy reference measurement of the bench_fleet "
                 "engine-driver workload; `cargo bench --bench "
                 "bench_fleet` regenerates this artifact with the real "
                 "engines (backend: rust)"),
        "cohort": COHORT,
        "shards": SHARDS,
        "rows": rows,
    }
    out = Path(__file__).resolve().parents[2] / "rust" / "BENCH_fleet.json"
    out.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()

"""Layer-2 JAX model: the paper's "simple neural network" for MNIST-like data.

A 784→HIDDEN→10 MLP whose dense layers and loss go through the layer-1
Pallas kernels (compile.kernels.linear / softmax_xent). Entry points:

  * ``train_step``   — one SGD step on a (B, 784) batch
  * ``train_epoch``  — one full local pass: ``lax.scan`` over the client's
                       batches (the per-client local-training unit the Rust
                       coordinator invokes ``local_epoch`` times)
  * ``eval_chunk``   — correct-prediction count over an eval chunk
  * ``predict``      — argmax class predictions

All are pure functions over an explicit parameter tuple
``(w1, b1, w2, b2)`` so they AOT-lower to HLO with a flat, stable signature
the Rust runtime can feed positionally (see aot.py / manifest.json).
"""

import jax
import jax.numpy as jnp

from compile.kernels import linear as klinear
from compile.kernels import sgd as ksgd
from compile.kernels import softmax_xent as kxent

INPUT_DIM = 784
HIDDEN_DIM = 128
NUM_CLASSES = 10

PARAM_NAMES = ("w1", "b1", "w2", "b2")
PARAM_SHAPES = (
    (INPUT_DIM, HIDDEN_DIM),
    (HIDDEN_DIM,),
    (HIDDEN_DIM, NUM_CLASSES),
    (NUM_CLASSES,),
)


def param_count() -> int:
    """Total scalar parameter count (101 770 for the default dims)."""
    n = 0
    for s in PARAM_SHAPES:
        c = 1
        for d in s:
            c *= d
        n += c
    return n


def init_params(seed: int = 0):
    """He-initialised parameter tuple, deterministic in ``seed``."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, PARAM_SHAPES[0], jnp.float32) * jnp.sqrt(
        2.0 / INPUT_DIM
    )
    b1 = jnp.zeros(PARAM_SHAPES[1], jnp.float32)
    w2 = jax.random.normal(k2, PARAM_SHAPES[2], jnp.float32) * jnp.sqrt(
        2.0 / HIDDEN_DIM
    )
    b2 = jnp.zeros(PARAM_SHAPES[3], jnp.float32)
    return w1, b1, w2, b2


def forward(params, x):
    """Logits [B, 10] for inputs [B, 784] — both layers are Pallas calls."""
    w1, b1, w2, b2 = params
    h = klinear.linear_relu(x, w1, b1)
    return klinear.linear(h, w2, b2)


def loss_fn(params, x, y):
    """Mean cross-entropy via the fused Pallas softmax-xent kernel."""
    return kxent.softmax_xent(forward(params, x), y)


def train_step(w1, b1, w2, b2, x, y, lr):
    """One SGD step. Flat signature for AOT export.

    Args:
      w1..b2: parameter tensors.
      x: f32[B, 784] batch inputs.
      y: i32[B] labels.
      lr: f32[] learning rate.
    Returns:
      (w1', b1', w2', b2', loss)
    """
    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new = ksgd.sgd_update_tree(params, grads, lr)
    return (*new, loss)


def train_epoch(w1, b1, w2, b2, x, y, lr):
    """One local epoch: scan SGD over pre-batched data.

    Args:
      x: f32[NB, B, 784] — the client's data reshaped to NB batches of B.
      y: i32[NB, B].
    Returns:
      (w1', b1', w2', b2', mean_loss)

    ``lax.scan`` keeps the lowered HLO one compact loop instead of NB
    unrolled copies of the step (see DESIGN.md §Perf L2).
    """
    params = (w1, b1, w2, b2)

    def body(p, batch):
        bx, by = batch
        loss, grads = jax.value_and_grad(loss_fn)(p, bx, by)
        return ksgd.sgd_update_tree(p, grads, lr), loss

    params, losses = jax.lax.scan(body, params, (x, y))
    return (*params, jnp.mean(losses))


def eval_chunk(w1, b1, w2, b2, x, y):
    """Correct-prediction count (i32[]) over an eval chunk [N, 784]."""
    pred = jnp.argmax(forward((w1, b1, w2, b2), x), axis=-1)
    return (jnp.sum((pred == y).astype(jnp.int32)),)


def predict(w1, b1, w2, b2, x):
    """Argmax class ids (i32[N]) — used by the quickstart example."""
    return (jnp.argmax(forward((w1, b1, w2, b2), x), axis=-1).astype(jnp.int32),)

"""Pallas fused-linear kernels (layer 1).

The compute hot-spot of local training in the paper's FL simulation is the
dense layer: ``y = x @ W + b`` (optionally ReLU-fused) and its backward
products ``dx = dy @ W^T``, ``dW = x^T @ dy``, ``db = sum(dy)``. These are
written as blocked Pallas kernels and wired into the layer-2 model through
``jax.custom_vjp`` so both the forward and backward passes of the exported
HLO go through Pallas.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid tiles the (M, N) output space; each grid step owns a
    (BM, K) x (K, BN) panel — K is kept whole per block because the model's
    K ∈ {784, 128} fits VMEM trivially (784·128·4 B ≈ 0.4 MB ≪ 16 MB).
  * BlockSpec expresses the HBM→VMEM schedule; the MXU consumes
    (128, 128)-aligned tiles, fp32 accumulation via
    ``preferred_element_type``.
  * ``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
    custom-calls; interpret mode traces the same kernel body to plain HLO.

Shapes that do not divide the tile are zero-padded in the wrappers (zero
rows/cols are exact no-ops for matmul, bias add, ReLU and the backward
reductions) and the result is sliced back — kernels stay mask-free.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic-array edge; the M tile is
# smaller because FL batches are small (B = 10 in the paper's Table 1).
BM = 128
BN = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pad2(a, rows: int, cols: int):
    """Zero-pad a 2-D array up to (rows, cols)."""
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _pad1(a, n: int):
    p = n - a.shape[0]
    return a if p == 0 else jnp.pad(a, (0, p))


# ---------------------------------------------------------------------------
# forward kernel: out = x @ w + b  (+ ReLU when fused)
# ---------------------------------------------------------------------------

def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One (BM, BN) output tile: full-K panel matmul + bias (+ ReLU)."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _linear_call(x, w, b, relu: bool, bm: int = BM, bn: int = BN):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert b.shape == (n,)
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_, = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = _pad2(x, mp, k)
    wp = _pad2(w, k, np_)
    bp = _pad1(b, np_)
    out = pl.pallas_call(
        functools.partial(_linear_kernel, relu=relu),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# backward kernels
# ---------------------------------------------------------------------------

def _matmul_kernel(a_ref, b_ref, o_ref):
    """Plain (BM, BN) tile of a @ b with fp32 accumulation (used for dx/dW)."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def matmul(a, b, bm: int = BM, bn: int = BN):
    """Blocked Pallas matmul a[M,K] @ b[K,N] — building block for backward."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    ap = _pad2(a, mp, k)
    bp = _pad2(b, k, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def _colsum_kernel(a_ref, o_ref):
    """Column sum of one (M, BN) panel → (BN,) (db = sum_rows dy)."""
    o_ref[...] = jnp.sum(a_ref[...], axis=0)


def colsum(a, bn: int = BN):
    """db: column-sum of dy[M, N] via a Pallas reduction kernel."""
    m, n = a.shape
    bn = min(bn, _ceil_to(n, 8))
    np_ = _ceil_to(n, bn)
    ap = _pad2(a, m, np_)
    out = pl.pallas_call(
        _colsum_kernel,
        grid=(np_ // bn,),
        in_specs=[pl.BlockSpec((m, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bn,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(ap)
    return out[:n]


# ---------------------------------------------------------------------------
# custom_vjp wrappers — the public layer-1 API used by model.py
# ---------------------------------------------------------------------------

@jax.custom_vjp
def linear(x, w, b):
    """Pallas fused linear ``x @ W + b`` with a Pallas backward pass."""
    return _linear_call(x, w, b, relu=False)


def _linear_fwd(x, w, b):
    return _linear_call(x, w, b, relu=False), (x, w)


def _linear_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = colsum(dy)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)


@jax.custom_vjp
def linear_relu(x, w, b):
    """Pallas fused linear+ReLU with a Pallas backward pass."""
    return _linear_call(x, w, b, relu=True)


def _linear_relu_fwd(x, w, b):
    y = _linear_call(x, w, b, relu=True)
    # Save the *activated* output: relu'(pre) == (y > 0) except at exactly 0,
    # where both conventions give zero gradient flow — matches ref.relu_mask.
    return y, (x, w, y)


def _linear_relu_bwd(res, dy):
    x, w, y = res
    dy = jnp.where(y > 0.0, dy, 0.0)
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = colsum(dy)
    return dx, dw, db


linear_relu.defvjp(_linear_relu_fwd, _linear_relu_bwd)

"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (same-math, same-dtype)
counterpart here. pytest asserts ``assert_allclose(kernel, ref)`` over
hypothesis-driven shape/dtype sweeps — this file is the correctness anchor
for layer 1.

All functions are pure jnp (no pallas, no custom_vjp) so they are also
differentiable with plain ``jax.grad`` and serve as gradient oracles.
"""

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# fused linear: y = x @ W + b, optionally ReLU-activated
# ---------------------------------------------------------------------------

def linear(x, w, b):
    """y = x @ W + b with fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b


def linear_relu(x, w, b):
    """y = relu(x @ W + b)."""
    return jnp.maximum(linear(x, w, b), 0.0)


def linear_bwd(x, w, dy):
    """Backward of ``linear`` w.r.t. (x, w, b) given upstream dy.

    Returns (dx, dw, db). For ``linear_relu`` pre-mask dy with the
    activation mask before calling (see ``relu_mask``).
    """
    dx = jnp.dot(dy, w.T, preferred_element_type=jnp.float32)
    dw = jnp.dot(x.T, dy, preferred_element_type=jnp.float32)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


def relu_mask(pre, dy):
    """Mask upstream gradient by the ReLU activation pattern of ``pre``."""
    return jnp.where(pre > 0.0, dy, 0.0)


# ---------------------------------------------------------------------------
# fused softmax cross-entropy over integer labels
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels):
    """Mean cross-entropy loss of ``logits`` [B, C] against int labels [B].

    Numerically-stable log-softmax; returns a scalar f32.
    """
    shifted = logits - jnp.max(logits, axis=-1, keepdims=True)
    log_z = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    log_probs = shifted - log_z
    nll = -jnp.take_along_axis(log_probs, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def softmax_xent_grad(logits, labels):
    """d loss / d logits for ``softmax_xent``: (softmax(z) - onehot) / B."""
    b, c = logits.shape
    shifted = logits - jnp.max(logits, axis=-1, keepdims=True)
    exp = jnp.exp(shifted)
    probs = exp / jnp.sum(exp, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(labels, c, dtype=logits.dtype)
    return (probs - onehot) / b


# ---------------------------------------------------------------------------
# whole-model reference (mirrors model.py but with zero pallas involvement)
# ---------------------------------------------------------------------------

def mlp_forward(params, x):
    """Reference 784→H→10 MLP forward. params = (w1, b1, w2, b2)."""
    w1, b1, w2, b2 = params
    h = linear_relu(x, w1, b1)
    return linear(h, w2, b2)


def mlp_loss(params, x, y):
    return softmax_xent(mlp_forward(params, x), y)


def mlp_sgd_step(params, x, y, lr):
    """One SGD step on a batch; returns (new_params, loss)."""
    loss, grads = jax.value_and_grad(mlp_loss)(params, x, y)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params, loss


def mlp_accuracy(params, x, y):
    """Count of correct predictions (int32) over the chunk."""
    pred = jnp.argmax(mlp_forward(params, x), axis=-1)
    return jnp.sum((pred == y).astype(jnp.int32))

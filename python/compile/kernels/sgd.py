"""Pallas fused SGD parameter update (layer 1).

``p' = p - lr · g`` for every parameter tensor — the last remaining
elementwise stage of the training step, fused into a single tiled Pallas
kernel per tensor so the whole SGD step (forward, backward, update) runs
through layer-1 kernels.

1-D tiling over the flattened parameter (the update is shape-agnostic);
tail blocks are handled by zero-padding in the wrapper, like linear.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One grid step covers the largest model tensor (w1: 784·128 = 100 352
# elements). 3 operands × 512 KiB ≈ 1.5 MiB ≪ 16 MiB VMEM, and interpret
# mode pays per grid step, so bigger is strictly better here (§Perf: this
# cut the fused-epoch wall by reducing ~28 grid iterations per SGD step
# to 4).
BLOCK = 131072


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(p, g, lr):
    """p - lr·g via the Pallas kernel; works for any tensor shape."""
    assert p.shape == g.shape, f"shape mismatch {p.shape} vs {g.shape}"
    flat_p = p.reshape(-1)
    flat_g = g.reshape(-1)
    n = flat_p.shape[0]
    block = min(BLOCK, _ceil_to(n, 8))
    np_ = _ceil_to(n, block)
    if np_ != n:
        flat_p = jnp.pad(flat_p, (0, np_ - n))
        flat_g = jnp.pad(flat_g, (0, np_ - n))
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(np_ // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=True,
    )(flat_p, flat_g, lr_arr)
    return out[:n].reshape(p.shape)


def sgd_update_tree(params, grads, lr):
    """Apply the fused update across a parameter tuple/pytree."""
    return jax.tree_util.tree_map(lambda p, g: sgd_update(p, g, lr), params, grads)

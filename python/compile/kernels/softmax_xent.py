"""Pallas fused softmax-cross-entropy kernel (layer 1).

Computes the mean cross-entropy of logits [B, C] against integer labels [B]
in a single fused kernel (max, exp, sum, log, gather via iota-compare) and
its gradient ``(softmax(z) - onehot) / B`` in a second kernel — both used by
the layer-2 model through ``jax.custom_vjp``.

Rows are tiled along the batch dimension; the class dimension C (= 10 here)
always stays whole inside a block, which is the natural TPU layout (the
row-reduction happens across lanes). Padded rows are written but sliced away
by the wrapper before the mean, so kernels stay mask-free (see linear.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _nll_kernel(z_ref, y_ref, o_ref):
    """Per-row negative log-likelihood for one (BM, C) tile of logits."""
    z = z_ref[...]
    y = y_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    shifted = z - zmax
    log_z = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    # gather log p[y] without dynamic indexing: iota-compare one-hot dot
    c = z.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == y[:, None])
    picked = jnp.sum(jnp.where(onehot, shifted, 0.0), axis=-1)
    o_ref[...] = log_z - picked


def _grad_kernel(z_ref, y_ref, scale_ref, o_ref):
    """(softmax(z) - onehot) * scale for one tile; scale = 1/B (true B)."""
    z = z_ref[...]
    y = y_ref[...]
    zmax = jnp.max(z, axis=-1, keepdims=True)
    exp = jnp.exp(z - zmax)
    probs = exp / jnp.sum(exp, axis=-1, keepdims=True)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == y[:, None]
    ).astype(jnp.float32)
    o_ref[...] = (probs - onehot) * scale_ref[0]


def _nll_call(logits, labels, bm: int = BM):
    b, c = logits.shape
    bm = min(bm, _ceil_to(b, 8))
    bp = _ceil_to(b, bm)
    zp = jnp.pad(logits, ((0, bp - b), (0, 0)))
    yp = jnp.pad(labels, (0, bp - b))
    nll = pl.pallas_call(
        _nll_kernel,
        grid=(bp // bm,),
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.float32),
        interpret=True,
    )(zp, yp)
    return jnp.mean(nll[:b])


def _grad_call(logits, labels, bm: int = BM):
    b, c = logits.shape
    bm = min(bm, _ceil_to(b, 8))
    bp = _ceil_to(b, bm)
    zp = jnp.pad(logits, ((0, bp - b), (0, 0)))
    yp = jnp.pad(labels, (0, bp - b))
    scale = jnp.full((1,), 1.0 / b, dtype=jnp.float32)
    g = pl.pallas_call(
        _grad_kernel,
        grid=(bp // bm,),
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, c), jnp.float32),
        interpret=True,
    )(zp, yp, scale)
    return g[:b]


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Mean cross-entropy (scalar f32) via the fused Pallas kernel."""
    return _nll_call(logits, labels)


def _fwd(logits, labels):
    return _nll_call(logits, labels), (logits, labels)


def _bwd(res, g):
    logits, labels = res
    return g * _grad_call(logits, labels), None


softmax_xent.defvjp(_fwd, _bwd)

"""AOT compile path: lower every model entry point to HLO *text* artifacts.

Run once via ``make artifacts`` (``python -m compile.aot --out-dir ../artifacts``).
Python never runs again after this — the Rust coordinator loads the text with
``HloModuleProto::from_text_file``, compiles on the PJRT CPU client and
executes it on the request path.

Interchange is HLO **text**, not a serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``). The
text parser reassigns ids, so text round-trips cleanly. Lowering goes
stablehlo → XlaComputation with ``return_tuple=True``; the Rust side unpacks
with ``Literal::to_tuple``.

Alongside the ``.hlo.txt`` files we write ``manifest.json`` describing each
artifact's positional argument/output shapes+dtypes — the Rust runtime
validates its buffers against this at load time.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

BATCH_SIZE = 10  # paper Table 1: batch_size = 10

# Per-client dataset sizes for the paper's fleet configs:
#   traditional: num_clients = 100 → 600 samples; 60 → 1000 samples
#   peer-to-peer: 20 clients → 3000 samples; 8 clients → 7500 samples
EPOCH_VARIANTS = (600, 1000, 3000, 7500)
EVAL_CHUNK = 1000
PREDICT_CHUNK = 100


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation (tupled) → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs():
    return [_spec(s) for s in model.PARAM_SHAPES]


def _tensor_meta(name, spec):
    return {
        "name": name,
        "dtype": str(spec.dtype),
        "shape": list(spec.shape),
    }


def entry_points():
    """(artifact name, fn, [(arg_name, spec)], [(out_name, spec)]) tuples."""
    p_in = list(zip(model.PARAM_NAMES, _param_specs()))
    p_out = [(f"{n}_new", s) for n, s in p_in]
    eps = []

    # one SGD step on a single batch
    eps.append(
        (
            "train_step",
            model.train_step,
            p_in
            + [
                ("x", _spec((BATCH_SIZE, model.INPUT_DIM))),
                ("y", _spec((BATCH_SIZE,), jnp.int32)),
                ("lr", _spec((), jnp.float32)),
            ],
            p_out + [("loss", _spec((), jnp.float32))],
        )
    )

    # one local epoch per per-client dataset size
    for n_i in EPOCH_VARIANTS:
        nb = n_i // BATCH_SIZE
        eps.append(
            (
                f"train_epoch_{n_i}",
                model.train_epoch,
                p_in
                + [
                    ("x", _spec((nb, BATCH_SIZE, model.INPUT_DIM))),
                    ("y", _spec((nb, BATCH_SIZE), jnp.int32)),
                    ("lr", _spec((), jnp.float32)),
                ],
                p_out + [("mean_loss", _spec((), jnp.float32))],
            )
        )

    # pure-jnp reference epoch (no Pallas) — the §Perf interpret-overhead
    # ablation comparator (bench_runtime measures both)
    from compile.kernels import ref as kref

    def train_epoch_ref(w1, b1, w2, b2, x, y, lr):
        params = (w1, b1, w2, b2)

        def body(p, batch):
            bx, by = batch
            loss, grads = jax.value_and_grad(kref.mlp_loss)(p, bx, by)
            return tuple(pi - lr * gi for pi, gi in zip(p, grads)), loss

        params, losses = jax.lax.scan(body, params, (x, y))
        return (*params, jnp.mean(losses))

    nb = 600 // BATCH_SIZE
    eps.append(
        (
            "train_epoch_ref_600",
            train_epoch_ref,
            p_in
            + [
                ("x", _spec((nb, BATCH_SIZE, model.INPUT_DIM))),
                ("y", _spec((nb, BATCH_SIZE), jnp.int32)),
                ("lr", _spec((), jnp.float32)),
            ],
            p_out + [("mean_loss", _spec((), jnp.float32))],
        )
    )

    eps.append(
        (
            f"eval_{EVAL_CHUNK}",
            model.eval_chunk,
            p_in
            + [
                ("x", _spec((EVAL_CHUNK, model.INPUT_DIM))),
                ("y", _spec((EVAL_CHUNK,), jnp.int32)),
            ],
            [("correct", _spec((), jnp.int32))],
        )
    )

    eps.append(
        (
            f"predict_{PREDICT_CHUNK}",
            model.predict,
            p_in + [("x", _spec((PREDICT_CHUNK, model.INPUT_DIM)))],
            [("classes", _spec((PREDICT_CHUNK,), jnp.int32))],
        )
    )
    return eps


def lower_all(out_dir: str, verbose: bool = True) -> dict:
    """Lower every entry point; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "model": {
            "input_dim": model.INPUT_DIM,
            "hidden_dim": model.HIDDEN_DIM,
            "num_classes": model.NUM_CLASSES,
            "param_count": model.param_count(),
            "param_names": list(model.PARAM_NAMES),
            "param_shapes": [list(s) for s in model.PARAM_SHAPES],
            "batch_size": BATCH_SIZE,
        },
        "artifacts": {},
    }
    for name, fn, args, outs in entry_points():
        specs = [s for _, s in args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "args": [_tensor_meta(n, s) for n, s in args],
            "outputs": [_tensor_meta(n, s) for n, s in outs],
        }
        if verbose:
            print(f"  {name}: {len(text)} chars -> {path}")
    # initial global model parameters, deterministic, as raw f32 little-endian
    params = model.init_params(seed=0)
    import numpy as np

    blob = b"".join(np.asarray(p, dtype=np.float32).tobytes() for p in params)
    init_path = os.path.join(out_dir, "init_params.f32.bin")
    with open(init_path, "wb") as f:
        f.write(blob)
    manifest["init_params"] = {
        "file": "init_params.f32.bin",
        "bytes": len(blob),
        "seed": 0,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  manifest -> {mpath}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quiet", action="store_true")
    ns = ap.parse_args()
    lower_all(ns.out_dir, verbose=not ns.quiet)


if __name__ == "__main__":
    main()

"""L2 correctness: the Pallas-backed MLP vs the pure-jnp reference model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def synth_batch(b, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, model.INPUT_DIM)).astype(np.float32))
    y = jnp.asarray(
        rng.integers(0, model.NUM_CLASSES, size=(b,)).astype(np.int32)
    )
    return x, y


def test_param_shapes_and_count():
    params = model.init_params(0)
    for p, s in zip(params, model.PARAM_SHAPES):
        assert p.shape == s
    assert model.param_count() == 784 * 128 + 128 + 128 * 10 + 10


def test_init_params_deterministic():
    a = model.init_params(42)
    b = model.init_params(42)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_init_params_differ_across_seeds():
    a = model.init_params(0)
    b = model.init_params(1)
    assert not np.allclose(np.asarray(a[0]), np.asarray(b[0]))


def test_forward_matches_ref():
    params = model.init_params(0)
    x, _ = synth_batch(10, 0)
    np.testing.assert_allclose(
        model.forward(params, x),
        ref.mlp_forward(params, x),
        rtol=1e-4,
        atol=1e-4,
    )


def test_loss_matches_ref():
    params = model.init_params(0)
    x, y = synth_batch(10, 1)
    np.testing.assert_allclose(
        model.loss_fn(params, x, y),
        ref.mlp_loss(params, x, y),
        rtol=1e-4,
        atol=1e-5,
    )


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_train_step_matches_ref_step(seed):
    params = model.init_params(0)
    x, y = synth_batch(10, seed)
    lr = jnp.float32(0.01)
    got = model.train_step(*params, x, y, lr)
    want_params, want_loss = ref.mlp_sgd_step(params, x, y, lr)
    for g, w in zip(got[:4], want_params):
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(got[4], want_loss, rtol=1e-4, atol=1e-5)


def test_train_epoch_equals_sequential_steps():
    """scan-based train_epoch == calling train_step per batch in order."""
    params = model.init_params(3)
    nb, b = 6, 10
    rng = np.random.default_rng(7)
    x = jnp.asarray(
        rng.normal(size=(nb, b, model.INPUT_DIM)).astype(np.float32)
    )
    y = jnp.asarray(
        rng.integers(0, model.NUM_CLASSES, size=(nb, b)).astype(np.int32)
    )
    lr = jnp.float32(0.01)
    got = model.train_epoch(*params, x, y, lr)

    p = params
    losses = []
    for i in range(nb):
        out = model.train_step(*p, x[i], y[i], lr)
        p, losses = out[:4], losses + [out[4]]
    for g, w in zip(got[:4], p):
        np.testing.assert_allclose(g, w, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(
        got[4], jnp.mean(jnp.stack(losses)), rtol=1e-4, atol=1e-5
    )


def test_training_reduces_loss_on_separable_data():
    """A few epochs on clustered data must cut the loss substantially."""
    rng = np.random.default_rng(0)
    protos = rng.normal(size=(model.NUM_CLASSES, model.INPUT_DIM)).astype(
        np.float32
    )
    n = 200
    labels = rng.integers(0, model.NUM_CLASSES, size=n)
    xs = protos[labels] + 0.3 * rng.normal(size=(n, model.INPUT_DIM)).astype(
        np.float32
    )
    x = jnp.asarray(xs.reshape(20, 10, model.INPUT_DIM))
    y = jnp.asarray(labels.reshape(20, 10).astype(np.int32))
    params = model.init_params(0)
    lr = jnp.float32(0.05)
    first_loss = None
    for _ in range(5):
        out = model.train_epoch(*params, x, y, lr)
        params = out[:4]
        if first_loss is None:
            first_loss = float(out[4])
        last_loss = float(out[4])
    assert last_loss < 0.5 * first_loss, (first_loss, last_loss)


def test_eval_chunk_counts_correct_predictions():
    params = model.init_params(0)
    x, _ = synth_batch(50, 11)
    pred = np.asarray(
        jnp.argmax(ref.mlp_forward(params, x), axis=-1), dtype=np.int32
    )
    y = jnp.asarray(pred)  # use the model's own predictions as labels
    (correct,) = model.eval_chunk(*params, x, y)
    assert int(correct) == 50


def test_eval_chunk_zero_when_all_wrong():
    params = model.init_params(0)
    x, _ = synth_batch(30, 13)
    pred = np.asarray(
        jnp.argmax(ref.mlp_forward(params, x), axis=-1), dtype=np.int32
    )
    y = jnp.asarray((pred + 1) % model.NUM_CLASSES)
    (correct,) = model.eval_chunk(*params, x, y)
    assert int(correct) == 0


def test_predict_matches_forward_argmax():
    params = model.init_params(0)
    x, _ = synth_batch(100, 17)
    (classes,) = model.predict(*params, x)
    want = jnp.argmax(ref.mlp_forward(params, x), axis=-1)
    np.testing.assert_array_equal(
        np.asarray(classes), np.asarray(want, dtype=np.int32)
    )


def test_train_step_is_deterministic():
    params = model.init_params(5)
    x, y = synth_batch(10, 23)
    lr = jnp.float32(0.01)
    a = model.train_step(*params, x, y, lr)
    b = model.train_step(*params, x, y, lr)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))

"""L1 correctness: Pallas fused-linear kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (including tile-unaligned ones that exercise the
zero-padding path) and asserts allclose against compile.kernels.ref for the
forward values and for all three gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear as kl
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.integers(min_value=1, max_value=200)


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_linear_matches_ref(m, k, n, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1, 0.1)
    b = rand((n,), seed + 2)
    np.testing.assert_allclose(
        kl.linear(x, w, b), ref.linear(x, w, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_linear_relu_matches_ref(m, k, n, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1, 0.1)
    b = rand((n,), seed + 2)
    np.testing.assert_allclose(
        kl.linear_relu(x, w, b), ref.linear_relu(x, w, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_pallas_matmul_matches_jnp(m, k, n, seed):
    a = rand((m, k), seed)
    b = rand((k, n), seed + 1)
    np.testing.assert_allclose(
        kl.matmul(a, b),
        jnp.dot(a, b, preferred_element_type=jnp.float32),
        rtol=1e-4,
        atol=1e-4,
    )


@settings(max_examples=20, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_colsum_matches_jnp(m, n, seed):
    a = rand((m, n), seed)
    np.testing.assert_allclose(
        kl.colsum(a), jnp.sum(a, axis=0), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_grads_match_ref(m, k, n, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1, 0.1)
    b = rand((n,), seed + 2)

    def f_kernel(x, w, b):
        return jnp.sum(jnp.sin(kl.linear(x, w, b)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.linear(x, w, b)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=5e-4, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_relu_grads_match_ref(m, k, n, seed):
    x = rand((m, k), seed)
    w = rand((k, n), seed + 1, 0.1)
    # offset bias away from 0 so the ReLU kink never sits on a sample point
    b = rand((n,), seed + 2) + 0.05

    def f_kernel(x, w, b):
        return jnp.sum(kl.linear_relu(x, w, b) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.linear_relu(x, w, b) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=5e-4, atol=5e-4)


def test_relu_zero_region_gradient_is_zero():
    """Gradient must not flow through inactive units."""
    x = jnp.full((4, 8), -1.0, jnp.float32)
    w = jnp.eye(8, dtype=jnp.float32)[:, :8]
    b = jnp.zeros((8,), jnp.float32)
    g = jax.grad(lambda x: jnp.sum(kl.linear_relu(x, w, b)))(x)
    np.testing.assert_array_equal(np.asarray(g), np.zeros_like(g))


def test_exact_tile_shapes_no_padding_path():
    """Shapes that are exact tile multiples skip padding — still correct."""
    x = rand((128, 256), 7)
    w = rand((256, 128), 8, 0.05)
    b = rand((128,), 9)
    np.testing.assert_allclose(
        kl.linear(x, w, b), ref.linear(x, w, b), rtol=1e-4, atol=1e-4
    )


def test_single_row_single_col():
    x = rand((1, 3), 1)
    w = rand((3, 1), 2)
    b = rand((1,), 3)
    np.testing.assert_allclose(
        kl.linear(x, w, b), ref.linear(x, w, b), rtol=1e-5, atol=1e-5
    )


def test_linear_under_jit_and_vmap_composition():
    """The kernels must compose with jit (they are jitted in train_epoch)."""
    x = rand((10, 784), 0)
    w = rand((784, 128), 1, 0.05)
    b = rand((128,), 2)
    jitted = jax.jit(kl.linear_relu)
    np.testing.assert_allclose(
        jitted(x, w, b), ref.linear_relu(x, w, b), rtol=1e-4, atol=1e-4
    )


def test_custom_tile_sizes():
    """Non-default (bm, bn) tilings give identical results."""
    x = rand((50, 70), 11)
    w = rand((70, 30), 12, 0.1)
    b = rand((30,), 13)
    want = ref.linear(x, w, b)
    for bm, bn in [(8, 8), (16, 32), (64, 128)]:
        got = kl._linear_call(x, w, b, relu=False, bm=bm, bn=bn)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

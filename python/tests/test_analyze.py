"""Tests for the §Perf analysis tool and its structural invariants."""

import os

import pytest

from compile import analyze, aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.lower_all(str(out), verbose=False)
    return str(out)


def test_report_generates_and_persists(built):
    report = analyze.analyze(built)
    assert "## L1 — Pallas kernel" in report
    assert "## L2 — HLO census" in report
    assert os.path.exists(os.path.join(built, "perf_analysis.md"))


def test_vmem_within_budget():
    for r in analyze.kernel_vmem_report():
        assert r["vmem_frac"] < 0.05, r  # tiny model ≪ 16 MB VMEM
        assert 0.0 < r["mxu_util"] <= 1.0


def test_hlo_census_structure(built):
    import json

    manifest = json.load(open(os.path.join(built, "manifest.json")))
    step = analyze.hlo_census(
        os.path.join(built, manifest["artifacts"]["train_step"]["file"])
    )
    epoch = analyze.hlo_census(
        os.path.join(built, manifest["artifacts"]["train_epoch_600"]["file"])
    )
    # the model's matmuls appear as dot ops
    assert step["dots"] >= 4  # fwd x2 + bwd dx/dW x2 at least
    # scan keeps the loop rolled
    assert epoch["while_loops"] >= 1
    assert epoch["bytes"] < 3 * step["bytes"]
    # interpret-mode pallas must not leave custom-calls behind
    assert step["custom_calls"] == 0
    assert epoch["custom_calls"] == 0


def test_entry_flops_scaling():
    step = analyze.entry_flops("train_step")
    epoch = analyze.entry_flops("train_epoch_600")
    assert epoch == 60 * step
    assert analyze.entry_flops("train_epoch_1000") == 100 * step
    assert analyze.entry_flops("eval_1000") > 0
    assert analyze.entry_flops("unknown") == 0

"""L1 correctness: Pallas fused softmax-cross-entropy vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import softmax_xent as kx
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_logits(b, c, seed, scale=3.0):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.normal(size=(b, c)).astype(np.float32) * scale)
    y = jnp.asarray(rng.integers(0, c, size=(b,)).astype(np.int32))
    return z, y


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 300),
    c=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_loss_matches_ref(b, c, seed):
    z, y = rand_logits(b, c, seed)
    np.testing.assert_allclose(
        kx.softmax_xent(z, y), ref.softmax_xent(z, y), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 128),
    c=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_grad_matches_ref(b, c, seed):
    z, y = rand_logits(b, c, seed)
    gk = jax.grad(lambda z: kx.softmax_xent(z, y))(z)
    gr = ref.softmax_xent_grad(z, y)
    np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-6)


def test_loss_of_perfect_prediction_is_small():
    """Huge correct-class logit → near-zero loss."""
    z = jnp.full((8, 10), -20.0, jnp.float32)
    y = jnp.arange(8, dtype=jnp.int32)
    z = z.at[jnp.arange(8), y].set(20.0)
    assert float(kx.softmax_xent(z, y)) < 1e-5


def test_loss_of_uniform_logits_is_log_c():
    z = jnp.zeros((16, 10), jnp.float32)
    y = jnp.zeros((16,), jnp.int32)
    np.testing.assert_allclose(
        float(kx.softmax_xent(z, y)), float(np.log(10.0)), rtol=1e-6
    )


def test_numerical_stability_large_logits():
    """Shifted log-sum-exp must not overflow at |z| = 1e4."""
    z, y = rand_logits(32, 10, 0, scale=1e4)
    got = float(kx.softmax_xent(z, y))
    want = float(ref.softmax_xent(z, y))
    assert np.isfinite(got)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_grad_rows_sum_to_zero():
    """Each row of d loss/d z sums to 0 (softmax minus one-hot)."""
    z, y = rand_logits(64, 10, 3)
    g = jax.grad(lambda z: kx.softmax_xent(z, y))(z)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(g, axis=-1)), np.zeros(64), atol=1e-7
    )


def test_batch_larger_than_tile():
    """B > BM exercises the multi-tile grid path."""
    z, y = rand_logits(kx.BM * 2 + 37, 10, 5)
    np.testing.assert_allclose(
        kx.softmax_xent(z, y), ref.softmax_xent(z, y), rtol=1e-5, atol=1e-5
    )


def test_under_jit():
    z, y = rand_logits(10, 10, 9)
    np.testing.assert_allclose(
        jax.jit(kx.softmax_xent)(z, y),
        ref.softmax_xent(z, y),
        rtol=1e-5,
        atol=1e-5,
    )

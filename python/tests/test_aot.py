"""AOT export tests: manifest structure, HLO text validity, determinism."""

import json
import os
import tempfile

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out), verbose=False)
    return str(out), manifest


def test_all_entry_points_exported(built):
    out, manifest = built
    names = set(manifest["artifacts"])
    want = {"train_step", "eval_1000", "predict_100", "train_epoch_ref_600"}
    want |= {f"train_epoch_{n}" for n in aot.EPOCH_VARIANTS}
    assert names == want
    for meta in manifest["artifacts"].values():
        assert os.path.exists(os.path.join(out, meta["file"]))


def test_hlo_text_is_parseable_hlo(built):
    out, manifest = built
    for meta in manifest["artifacts"].values():
        text = open(os.path.join(out, meta["file"])).read()
        assert text.startswith("HloModule"), meta["file"]
        assert "ENTRY" in text
        # the ROOT of the entry computation must be a tuple (return_tuple=True)
        assert "tuple(" in text


def test_manifest_train_step_signature(built):
    _, manifest = built
    a = manifest["artifacts"]["train_step"]
    arg_names = [x["name"] for x in a["args"]]
    assert arg_names == ["w1", "b1", "w2", "b2", "x", "y", "lr"]
    shapes = {x["name"]: tuple(x["shape"]) for x in a["args"]}
    assert shapes["w1"] == (784, 128)
    assert shapes["x"] == (10, 784)
    assert shapes["y"] == (10,)
    assert shapes["lr"] == ()
    outs = [x["name"] for x in a["outputs"]]
    assert outs == ["w1_new", "b1_new", "w2_new", "b2_new", "loss"]


def test_manifest_epoch_variants_shapes(built):
    _, manifest = built
    for n_i in aot.EPOCH_VARIANTS:
        a = manifest["artifacts"][f"train_epoch_{n_i}"]
        shapes = {x["name"]: tuple(x["shape"]) for x in a["args"]}
        nb = n_i // aot.BATCH_SIZE
        assert shapes["x"] == (nb, aot.BATCH_SIZE, model.INPUT_DIM)
        assert shapes["y"] == (nb, aot.BATCH_SIZE)


def test_manifest_dtypes(built):
    _, manifest = built
    a = manifest["artifacts"]["eval_1000"]
    d = {x["name"]: x["dtype"] for x in a["args"]}
    assert d["x"] == "float32"
    assert d["y"] == "int32"
    assert a["outputs"][0]["dtype"] == "int32"


def test_init_params_blob_size_and_determinism(built):
    out, manifest = built
    blob = open(os.path.join(out, manifest["init_params"]["file"]), "rb").read()
    assert len(blob) == model.param_count() * 4
    # regenerate → byte-identical (seeded)
    params = model.init_params(seed=0)
    blob2 = b"".join(
        np.asarray(p, dtype=np.float32).tobytes() for p in params
    )
    assert blob == blob2


def test_export_is_deterministic(built):
    """Lowering twice produces identical HLO text (stable hashes)."""
    out, manifest = built
    with tempfile.TemporaryDirectory() as out2:
        manifest2 = aot.lower_all(out2, verbose=False)
    for name, meta in manifest["artifacts"].items():
        assert meta["sha256"] == manifest2["artifacts"][name]["sha256"], name


def test_manifest_json_round_trips(built):
    out, _ = built
    m = json.load(open(os.path.join(out, "manifest.json")))
    assert m["model"]["param_count"] == model.param_count()
    assert m["model"]["batch_size"] == aot.BATCH_SIZE


def test_scan_not_unrolled_in_epoch_hlo(built):
    """train_epoch must lower to a while loop, not 60 unrolled steps —
    the L2 perf guarantee in DESIGN.md §Perf."""
    out, manifest = built
    step = open(
        os.path.join(out, manifest["artifacts"]["train_step"]["file"])
    ).read()
    epoch = open(
        os.path.join(out, manifest["artifacts"]["train_epoch_600"]["file"])
    ).read()
    assert "while(" in epoch or "while (" in epoch
    # an unrolled epoch would be ~60x the step module; a scan stays small
    assert len(epoch) < 3 * len(step)

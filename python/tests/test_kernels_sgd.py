"""L1 correctness: Pallas fused SGD update vs plain jnp arithmetic."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import sgd

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 20_000),
    lr=st.floats(1e-4, 1.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_flat_update_matches_reference(n, lr, seed):
    p = rand((n,), seed)
    g = rand((n,), seed + 1)
    got = sgd.sgd_update(p, g, lr)
    want = p - jnp.float32(lr) * g
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    r=st.integers(1, 100),
    c=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
)
def test_2d_shapes_preserved(r, c, seed):
    p = rand((r, c), seed)
    g = rand((r, c), seed + 1)
    got = sgd.sgd_update(p, g, 0.01)
    assert got.shape == (r, c)
    np.testing.assert_allclose(got, p - 0.01 * g, rtol=1e-6, atol=1e-6)


def test_zero_lr_is_identity():
    p = rand((1000,), 0)
    g = rand((1000,), 1)
    np.testing.assert_array_equal(
        np.asarray(sgd.sgd_update(p, g, 0.0)), np.asarray(p)
    )


def test_tree_update_covers_model_params():
    from compile import model

    params = model.init_params(0)
    grads = tuple(jnp.ones_like(p) for p in params)
    new = sgd.sgd_update_tree(params, grads, 0.5)
    for p, q in zip(params, new):
        np.testing.assert_allclose(q, p - 0.5, rtol=1e-6, atol=1e-6)


def test_block_boundary_sizes():
    """Exactly-BLOCK and BLOCK±1 exercise the padding path."""
    for n in [sgd.BLOCK - 1, sgd.BLOCK, sgd.BLOCK + 1, 2 * sgd.BLOCK]:
        p = rand((n,), n)
        g = rand((n,), n + 1)
        np.testing.assert_allclose(
            sgd.sgd_update(p, g, 0.1), p - 0.1 * g, rtol=1e-6, atol=1e-6
        )


def test_under_jit():
    p = rand((784, 128), 3)
    g = rand((784, 128), 4)
    got = jax.jit(lambda p, g: sgd.sgd_update(p, g, 0.01))(p, g)
    np.testing.assert_allclose(got, p - 0.01 * g, rtol=1e-6, atol=1e-6)
